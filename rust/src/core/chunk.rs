//! Chunks: the unit of data storage and transport (§3.1, Fig. 1).
//!
//! Sequential data elements (steps) are batched column-wise — one column per
//! signature field, stacked along a new leading "time" axis — and each
//! column is compressed independently. Sequential RL data is highly
//! redundant (e.g. Atari frames), so an optional delta filter subtracts the
//! previous row byte-wise before entropy coding, which is where the paper's
//! "up to 90% compression over 40-frame sequences" comes from.

use crate::core::tensor::{DType, Signature, Tensor};
use crate::error::{Error, Result};
use byteorder::{ByteOrder, LittleEndian};

/// How a chunk column's payload is encoded on the wire / in memory.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Compression {
    /// Raw bytes, no compression. Fastest; used for tiny payloads.
    None,
    /// zstd entropy coding of the raw column.
    Zstd { level: i32 },
    /// Byte-wise delta between consecutive rows, then zstd. Best for
    /// slowly-changing dense data (frames).
    DeltaZstd { level: i32 },
}

impl Compression {
    /// Default used by writers: cheap zstd.
    pub fn default_fast() -> Self {
        Compression::Zstd { level: 1 }
    }

    pub(crate) fn tag(self) -> u8 {
        match self {
            Compression::None => 0,
            Compression::Zstd { .. } => 1,
            Compression::DeltaZstd { .. } => 2,
        }
    }

    pub(crate) fn from_tag(tag: u8) -> Result<Self> {
        Ok(match tag {
            0 => Compression::None,
            1 => Compression::Zstd { level: 1 },
            2 => Compression::DeltaZstd { level: 1 },
            t => return Err(Error::Decode(format!("unknown compression tag {t}"))),
        })
    }
}

/// One per-column codec selection rule: match a column by writer-side
/// name (a `*` glob) and/or dtype, and pick its [`Compression`]. Rules
/// are checked in order; the first full match wins. This is how u8
/// frame-stack columns get `DeltaZstd` while scalar reward columns stay
/// uncompressed, shrinking cold-tier and wire bytes together.
#[derive(Clone, Debug, PartialEq)]
pub struct ColumnCodecRule {
    /// Column-name pattern; `*` matches any run of characters. `None`
    /// matches every name.
    pub name_glob: Option<String>,
    /// Required dtype; `None` matches every dtype.
    pub dtype: Option<DType>,
    /// Codec applied when the rule matches.
    pub codec: Compression,
}

impl ColumnCodecRule {
    /// Match columns by name pattern only.
    pub fn name(pattern: impl Into<String>, codec: Compression) -> Self {
        ColumnCodecRule {
            name_glob: Some(pattern.into()),
            dtype: None,
            codec,
        }
    }

    /// Match columns by dtype only.
    pub fn dtype(dtype: DType, codec: Compression) -> Self {
        ColumnCodecRule {
            name_glob: None,
            dtype: Some(dtype),
            codec,
        }
    }

    /// Whether this rule matches a column of `name` and `dtype`.
    pub fn matches(&self, name: &str, dtype: DType) -> bool {
        if let Some(want) = self.dtype {
            if want != dtype {
                return false;
            }
        }
        match &self.name_glob {
            None => true,
            Some(pattern) => glob_match(pattern, name),
        }
    }
}

/// First matching rule's codec, or `default` when none match. Dtype is
/// known only once a column's first cell arrives, which is why writers
/// pick codecs lazily at first append.
pub fn select_codec(
    rules: &[ColumnCodecRule],
    name: &str,
    dtype: DType,
    default: Compression,
) -> Compression {
    rules
        .iter()
        .find(|r| r.matches(name, dtype))
        .map(|r| r.codec)
        .unwrap_or(default)
}

/// Minimal `*`-only glob match (no character classes, no `?`), iterative
/// with the classic backtrack-to-last-star algorithm.
fn glob_match(pattern: &str, name: &str) -> bool {
    let (p, n) = (pattern.as_bytes(), name.as_bytes());
    let (mut pi, mut ni) = (0usize, 0usize);
    let mut star: Option<(usize, usize)> = None;
    while ni < n.len() {
        if pi < p.len() && p[pi] == b'*' {
            star = Some((pi, ni));
            pi += 1;
        } else if pi < p.len() && p[pi] == n[ni] {
            pi += 1;
            ni += 1;
        } else if let Some((sp, sn)) = star {
            pi = sp + 1;
            ni = sn + 1;
            star = Some((sp, sn + 1));
        } else {
            return false;
        }
    }
    while pi < p.len() && p[pi] == b'*' {
        pi += 1;
    }
    pi == p.len()
}

/// One compressed column of a chunk: the stacked per-step tensors of one
/// signature field.
#[derive(Clone, Debug)]
pub struct Column {
    pub dtype: DType,
    /// Shape of the *stacked* column: `[num_steps, per_step_shape...]`.
    pub shape: Vec<usize>,
    pub compression: Compression,
    /// Encoded payload.
    pub payload: Vec<u8>,
    /// Length of the raw (decoded) payload in bytes.
    pub uncompressed_len: usize,
}

impl Column {
    /// Encode a stacked column tensor.
    pub fn encode(stacked: &Tensor, compression: Compression) -> Result<Column> {
        let raw = stacked.bytes();
        let row_len = if stacked.shape().is_empty() || stacked.shape()[0] == 0 {
            0
        } else {
            raw.len() / stacked.shape()[0]
        };
        let payload = match compression {
            Compression::None => raw.to_vec(),
            Compression::Zstd { level } => zstd_compress(raw, level)?,
            Compression::DeltaZstd { level } => {
                let deltas = delta_encode(raw, row_len);
                zstd_compress(&deltas, level)?
            }
        };
        Ok(Column {
            dtype: stacked.dtype(),
            shape: stacked.shape().to_vec(),
            compression,
            payload,
            uncompressed_len: raw.len(),
        })
    }

    /// Decode back to the stacked column tensor.
    pub fn decode(&self) -> Result<Tensor> {
        let raw = match self.compression {
            Compression::None => self.payload.clone(),
            Compression::Zstd { .. } => zstd_decompress(&self.payload, self.uncompressed_len)?,
            Compression::DeltaZstd { .. } => {
                let deltas = zstd_decompress(&self.payload, self.uncompressed_len)?;
                let row_len = if self.shape.is_empty() || self.shape[0] == 0 {
                    0
                } else {
                    deltas.len() / self.shape[0]
                };
                delta_decode(&deltas, row_len)
            }
        };
        Tensor::from_bytes(self.dtype, self.shape.clone(), raw)
    }

    /// Encoded size in bytes.
    pub fn encoded_len(&self) -> usize {
        self.payload.len()
    }
}

fn zstd_compress(raw: &[u8], level: i32) -> Result<Vec<u8>> {
    zstd::bulk::compress(raw, level).map_err(|e| Error::Decode(format!("zstd compress: {e}")))
}

fn zstd_decompress(payload: &[u8], cap: usize) -> Result<Vec<u8>> {
    zstd::bulk::decompress(payload, cap).map_err(|e| Error::Decode(format!("zstd decompress: {e}")))
}

/// Subtract row `i-1` from row `i`, byte-wise with wrapping arithmetic.
/// Row 0 is stored verbatim.
fn delta_encode(raw: &[u8], row_len: usize) -> Vec<u8> {
    if row_len == 0 || raw.len() <= row_len {
        return raw.to_vec();
    }
    let mut out = Vec::with_capacity(raw.len());
    out.extend_from_slice(&raw[..row_len]);
    for i in (row_len..raw.len()).step_by(row_len) {
        let end = (i + row_len).min(raw.len());
        for j in i..end {
            out.push(raw[j].wrapping_sub(raw[j - row_len]));
        }
    }
    out
}

/// Inverse of [`delta_encode`].
fn delta_decode(deltas: &[u8], row_len: usize) -> Vec<u8> {
    if row_len == 0 || deltas.len() <= row_len {
        return deltas.to_vec();
    }
    let mut out = Vec::with_capacity(deltas.len());
    out.extend_from_slice(&deltas[..row_len]);
    for i in (row_len..deltas.len()).step_by(row_len) {
        let end = (i + row_len).min(deltas.len());
        for j in i..end {
            let prev = out[j - row_len];
            out.push(deltas[j].wrapping_add(prev));
        }
    }
    out
}

/// A chunk: `num_steps` sequential data elements batched column-wise and
/// compressed. Identified by a key unique within the writer's stream.
#[derive(Clone, Debug)]
pub struct Chunk {
    /// Globally (probabilistically) unique key.
    pub key: u64,
    /// Index of the first step of this chunk within its episode stream.
    pub sequence_start: u64,
    /// Number of steps (rows) in the chunk.
    pub num_steps: usize,
    /// One column per signature field, in signature order.
    pub columns: Vec<Column>,
}

impl Chunk {
    /// Build a chunk from `steps` (each a row of tensors in signature field
    /// order), compressing each column with `compression`.
    pub fn from_steps(
        key: u64,
        sequence_start: u64,
        steps: &[Vec<Tensor>],
        compression: Compression,
    ) -> Result<Chunk> {
        let first = steps
            .first()
            .ok_or_else(|| Error::InvalidArgument("chunk of zero steps".into()))?;
        let num_fields = first.len();
        let mut columns = Vec::with_capacity(num_fields);
        for f in 0..num_fields {
            let col_tensors: Vec<Tensor> = steps
                .iter()
                .map(|row| {
                    row.get(f).cloned().ok_or_else(|| {
                        Error::SignatureMismatch(format!("step missing field {f}"))
                    })
                })
                .collect::<Result<_>>()?;
            let stacked = Tensor::stack(&col_tensors)?;
            columns.push(Column::encode(&stacked, compression)?);
        }
        Ok(Chunk {
            key,
            sequence_start,
            num_steps: steps.len(),
            columns,
        })
    }

    /// Decode all columns back into per-step rows (inverse of
    /// [`Chunk::from_steps`]).
    pub fn to_steps(&self) -> Result<Vec<Vec<Tensor>>> {
        let mut cols: Vec<Vec<Tensor>> = Vec::with_capacity(self.columns.len());
        for c in &self.columns {
            cols.push(c.decode()?.unstack()?);
        }
        let mut steps = vec![Vec::with_capacity(self.columns.len()); self.num_steps];
        for col in cols {
            if col.len() != self.num_steps {
                return Err(Error::Decode(format!(
                    "column has {} rows, chunk has {} steps",
                    col.len(),
                    self.num_steps
                )));
            }
            for (i, t) in col.into_iter().enumerate() {
                steps[i].push(t);
            }
        }
        Ok(steps)
    }

    /// Decode only rows `[offset, offset+len)` of every column. This is the
    /// item materialization path (Fig. 3: offset & length select the exact
    /// steps within the chunk sequence).
    pub fn decode_rows(&self, offset: usize, len: usize) -> Result<Vec<Tensor>> {
        if offset + len > self.num_steps {
            return Err(Error::InvalidArgument(format!(
                "decode_rows [{offset}, {}) out of bounds for {} steps",
                offset + len,
                self.num_steps
            )));
        }
        self.columns
            .iter()
            .map(|c| {
                // Fast path: uncompressed columns can be sliced byte-wise
                // without materializing the full column first (hot on the
                // client sample-materialization path).
                if c.compression == Compression::None && !c.shape.is_empty() && c.shape[0] > 0 {
                    let rows = c.shape[0];
                    let row_len = c.payload.len() / rows;
                    let inner: Vec<usize> = c.shape[1..].to_vec();
                    let mut shape = Vec::with_capacity(c.shape.len());
                    shape.push(len);
                    shape.extend_from_slice(&inner);
                    return Tensor::from_bytes(
                        c.dtype,
                        shape,
                        c.payload[offset * row_len..(offset + len) * row_len].to_vec(),
                    );
                }
                c.decode()?.slice_rows(offset, len)
            })
            .collect()
    }

    /// Sum of encoded column payload sizes.
    pub fn encoded_len(&self) -> usize {
        self.columns.iter().map(|c| c.encoded_len()).sum()
    }

    /// Sum of raw (uncompressed) column sizes.
    pub fn uncompressed_len(&self) -> usize {
        self.columns.iter().map(|c| c.uncompressed_len).sum()
    }

    /// Compression ratio achieved: `1 - encoded/uncompressed`.
    pub fn compression_ratio(&self) -> f64 {
        let u = self.uncompressed_len();
        if u == 0 {
            return 0.0;
        }
        1.0 - self.encoded_len() as f64 / u as f64
    }

    /// Validate chunk columns against a signature (per-step shapes).
    pub fn validate_signature(&self, sig: &Signature) -> Result<()> {
        if self.columns.len() != sig.fields.len() {
            return Err(Error::SignatureMismatch(format!(
                "chunk has {} columns, signature has {} fields",
                self.columns.len(),
                sig.fields.len()
            )));
        }
        for (col, spec) in self.columns.iter().zip(&sig.fields) {
            if col.dtype != spec.dtype {
                return Err(Error::SignatureMismatch(format!(
                    "field {}: chunk dtype {} != spec {}",
                    spec.name, col.dtype, spec.dtype
                )));
            }
            // col.shape = [steps, per-step...]
            if col.shape.len() != spec.shape.len() + 1 {
                return Err(Error::SignatureMismatch(format!(
                    "field {}: chunk rank {} != spec rank {} + 1",
                    spec.name,
                    col.shape.len(),
                    spec.shape.len()
                )));
            }
            for (i, (&got, want)) in col.shape[1..].iter().zip(&spec.shape).enumerate() {
                if let Some(w) = want {
                    if got != *w {
                        return Err(Error::SignatureMismatch(format!(
                            "field {}: dim {i} is {got}, spec wants {w}",
                            spec.name
                        )));
                    }
                }
            }
        }
        Ok(())
    }
}

impl Chunk {
    /// Serialize to a binary stream (shared by the wire protocol and the
    /// checkpoint format).
    pub fn encode<W: std::io::Write>(&self, w: &mut W) -> Result<()> {
        use crate::io::*;
        put_u64(w, self.key)?;
        put_u64(w, self.sequence_start)?;
        put_u64(w, self.num_steps as u64)?;
        put_u32(w, self.columns.len() as u32)?;
        for col in &self.columns {
            put_u8(w, col.dtype.tag())?;
            put_shape(w, &col.shape)?;
            put_u8(w, col.compression.tag())?;
            put_u64(w, col.uncompressed_len as u64)?;
            put_bytes(w, &col.payload)?;
        }
        Ok(())
    }

    /// Inverse of [`Chunk::encode`].
    pub fn decode<R: std::io::Read>(r: &mut R) -> Result<Chunk> {
        use crate::io::*;
        let key = get_u64(r)?;
        let sequence_start = get_u64(r)?;
        let num_steps = get_u64(r)? as usize;
        let ncols = get_u32(r)? as usize;
        if ncols > 4096 {
            return Err(Error::Decode(format!("{ncols} columns exceeds limit")));
        }
        let mut columns = Vec::with_capacity(ncols);
        for _ in 0..ncols {
            let dtype = DType::from_tag(get_u8(r)?)?;
            let shape = get_shape(r)?;
            let compression = Compression::from_tag(get_u8(r)?)?;
            let uncompressed_len = get_u64(r)? as usize;
            let payload = get_bytes(r)?;
            columns.push(Column {
                dtype,
                shape,
                compression,
                payload,
                uncompressed_len,
            });
        }
        Ok(Chunk {
            key,
            sequence_start,
            num_steps,
            columns,
        })
    }
}

/// Incremental chunk builder used by writers: buffers appended steps and
/// emits a chunk every `chunk_length` steps (or on demand at episode end).
pub struct ChunkBuilder {
    chunk_length: usize,
    compression: Compression,
    buffered: Vec<Vec<Tensor>>,
    next_sequence: u64,
}

impl ChunkBuilder {
    pub fn new(chunk_length: usize, compression: Compression) -> Self {
        assert!(chunk_length > 0, "chunk_length must be positive");
        ChunkBuilder {
            chunk_length,
            compression,
            buffered: Vec::new(),
            next_sequence: 0,
        }
    }

    /// Append a step; returns a completed chunk when the buffer fills.
    pub fn append(&mut self, key: u64, step: Vec<Tensor>) -> Result<Option<Chunk>> {
        self.buffered.push(step);
        if self.buffered.len() >= self.chunk_length {
            self.flush(key)
        } else {
            Ok(None)
        }
    }

    /// Emit a (possibly short) chunk from whatever is buffered.
    ///
    /// Failure is atomic: the buffer and sequence counter are untouched
    /// on error, so cell positions already handed out (writer `StepRef`s)
    /// never silently re-bind to data appended later — the cut just fails
    /// again until the caller gives up.
    pub fn flush(&mut self, key: u64) -> Result<Option<Chunk>> {
        if self.buffered.is_empty() {
            return Ok(None);
        }
        let chunk = Chunk::from_steps(key, self.next_sequence, &self.buffered, self.compression)?;
        self.next_sequence += self.buffered.len() as u64;
        self.buffered.clear();
        Ok(Some(chunk))
    }

    /// Change the codec applied to future cuts. Compression is applied at
    /// cut time, so this is safe mid-buffer; writers use it to settle a
    /// column's codec once the first cell reveals its dtype.
    pub fn set_compression(&mut self, compression: Compression) {
        self.compression = compression;
    }

    /// The codec future cuts will use.
    pub fn compression(&self) -> Compression {
        self.compression
    }

    /// Number of steps currently buffered (not yet in a chunk).
    pub fn buffered_steps(&self) -> usize {
        self.buffered.len()
    }

    /// Stream position of the *next* appended step.
    pub fn next_sequence(&self) -> u64 {
        self.next_sequence + self.buffered.len() as u64
    }

    /// Reset episode state (sequence counter and buffer).
    pub fn reset(&mut self) {
        self.buffered.clear();
        self.next_sequence = 0;
    }
}

/// Build a correlated "frame-like" step for compression tests/benches:
/// `base + small noise`, mimicking consecutive Atari frames.
pub fn correlated_frame(base: &[u8], noise: &mut crate::util::rng::Pcg32, flips: usize) -> Vec<u8> {
    let mut frame = base.to_vec();
    for _ in 0..flips {
        let i = noise.gen_range(frame.len() as u64) as usize;
        frame[i] = frame[i].wrapping_add((noise.next_u32() & 0xF) as u8);
    }
    frame
}

/// Encode a f32 slice into raw little-endian bytes (bench helper).
pub fn f32s_to_bytes(xs: &[f32]) -> Vec<u8> {
    let mut out = vec![0u8; xs.len() * 4];
    LittleEndian::write_f32_into(xs, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::tensor::TensorSpec;
    use crate::util::rng::Pcg32;

    #[test]
    fn glob_match_star_patterns() {
        assert!(glob_match("*", ""));
        assert!(glob_match("*", "anything"));
        assert!(glob_match("obs/*", "obs/pixels"));
        assert!(!glob_match("obs/*", "act/pixels"));
        assert!(glob_match("*pixels", "obs/pixels"));
        assert!(glob_match("obs*frame*", "obs/stacked_frame_0"));
        assert!(!glob_match("obs", "observation"));
        assert!(glob_match("exact", "exact"));
        assert!(!glob_match("", "x"));
        assert!(glob_match("", ""));
    }

    #[test]
    fn codec_rules_first_match_wins() {
        let rules = vec![
            ColumnCodecRule::name("obs/*", Compression::DeltaZstd { level: 3 }),
            ColumnCodecRule::dtype(DType::U8, Compression::Zstd { level: 9 }),
            ColumnCodecRule::name("*", Compression::None),
        ];
        // Name rule beats the later dtype rule.
        assert_eq!(
            select_codec(&rules, "obs/pixels", DType::U8, Compression::default_fast()),
            Compression::DeltaZstd { level: 3 }
        );
        // Dtype rule catches u8 columns under other names.
        assert_eq!(
            select_codec(&rules, "aux/mask", DType::U8, Compression::default_fast()),
            Compression::Zstd { level: 9 }
        );
        // Catch-all.
        assert_eq!(
            select_codec(&rules, "reward", DType::F32, Compression::default_fast()),
            Compression::None
        );
    }

    #[test]
    fn codec_rules_fall_back_to_default() {
        let rules = vec![ColumnCodecRule::name("obs/*", Compression::None)];
        assert_eq!(
            select_codec(&rules, "reward", DType::F32, Compression::Zstd { level: 1 }),
            Compression::Zstd { level: 1 }
        );
    }

    #[test]
    fn codec_rule_requires_both_fields_when_set() {
        let rule = ColumnCodecRule {
            name_glob: Some("obs/*".to_string()),
            dtype: Some(DType::U8),
            codec: Compression::DeltaZstd { level: 1 },
        };
        assert!(rule.matches("obs/pixels", DType::U8));
        assert!(!rule.matches("obs/pixels", DType::F32));
        assert!(!rule.matches("act", DType::U8));
    }

    fn step(vals: &[f32], action: i32) -> Vec<Tensor> {
        vec![
            Tensor::from_f32(&[vals.len()], vals).unwrap(),
            Tensor::from_i32(&[], &[action]).unwrap(),
        ]
    }

    #[test]
    fn chunk_roundtrip_all_compressions() {
        for comp in [
            Compression::None,
            Compression::Zstd { level: 3 },
            Compression::DeltaZstd { level: 3 },
        ] {
            let steps = vec![step(&[1., 2.], 0), step(&[3., 4.], 1), step(&[5., 6.], 2)];
            let chunk = Chunk::from_steps(7, 10, &steps, comp).unwrap();
            assert_eq!(chunk.num_steps, 3);
            assert_eq!(chunk.sequence_start, 10);
            let back = chunk.to_steps().unwrap();
            assert_eq!(back.len(), 3);
            assert_eq!(back[1][0].to_f32().unwrap(), vec![3., 4.]);
            assert_eq!(back[2][1].to_i32().unwrap(), vec![2]);
        }
    }

    #[test]
    fn decode_rows_subrange() {
        let steps: Vec<_> = (0..5).map(|i| step(&[i as f32, 0.], i)).collect();
        let chunk = Chunk::from_steps(1, 0, &steps, Compression::Zstd { level: 1 }).unwrap();
        let rows = chunk.decode_rows(2, 2).unwrap();
        assert_eq!(rows[0].shape(), &[2, 2]);
        assert_eq!(rows[0].to_f32().unwrap(), vec![2., 0., 3., 0.]);
        assert_eq!(rows[1].to_i32().unwrap(), vec![2, 3]);
        assert!(chunk.decode_rows(4, 2).is_err());
    }

    #[test]
    fn delta_roundtrip_property() {
        crate::util::proptest::forall("delta encode/decode roundtrip", |rng| {
            let row = 1 + rng.gen_range(16) as usize;
            let rows = 1 + rng.gen_range(8) as usize;
            let mut raw = vec![0u8; row * rows];
            rng.fill_bytes(&mut raw);
            let enc = delta_encode(&raw, row);
            let dec = delta_decode(&enc, row);
            if dec == raw {
                Ok(())
            } else {
                Err(format!("row={row} rows={rows}"))
            }
        });
    }

    #[test]
    fn correlated_frames_compress_much_better_than_random() {
        let mut rng = Pcg32::new(1, 1);
        let mut base = vec![0u8; 84 * 84];
        rng.fill_bytes(&mut base[..200]); // sparse "sprites" on black bg

        // 40 correlated frames vs 40 random frames (paper: ~90% on Atari).
        let corr_steps: Vec<Vec<Tensor>> = (0..40)
            .map(|_| {
                base = correlated_frame(&base, &mut rng, 8);
                vec![Tensor::from_u8(&[84, 84], &base).unwrap()]
            })
            .collect();
        let rand_steps: Vec<Vec<Tensor>> = (0..40)
            .map(|_| {
                let mut f = vec![0u8; 84 * 84];
                rng.fill_bytes(&mut f);
                vec![Tensor::from_u8(&[84, 84], &f).unwrap()]
            })
            .collect();

        let corr = Chunk::from_steps(1, 0, &corr_steps, Compression::DeltaZstd { level: 1 }).unwrap();
        let rand = Chunk::from_steps(2, 0, &rand_steps, Compression::DeltaZstd { level: 1 }).unwrap();
        assert!(
            corr.compression_ratio() > 0.85,
            "correlated ratio {}",
            corr.compression_ratio()
        );
        assert!(
            rand.compression_ratio() < 0.05,
            "random ratio {}",
            rand.compression_ratio()
        );
        // And the round trip is still exact.
        assert_eq!(
            corr.to_steps().unwrap()[39][0].bytes(),
            corr_steps[39][0].bytes()
        );
    }

    #[test]
    fn builder_emits_on_boundary() {
        let mut b = ChunkBuilder::new(3, Compression::None);
        assert!(b.append(1, step(&[0.], 0)).unwrap().is_none());
        assert!(b.append(1, step(&[1.], 0)).unwrap().is_none());
        let c = b.append(1, step(&[2.], 0)).unwrap().unwrap();
        assert_eq!(c.num_steps, 3);
        assert_eq!(c.sequence_start, 0);
        // Next chunk continues the sequence numbering.
        assert!(b.append(2, step(&[3.], 0)).unwrap().is_none());
        let c2 = b.flush(2).unwrap().unwrap();
        assert_eq!(c2.num_steps, 1);
        assert_eq!(c2.sequence_start, 3);
        assert!(b.flush(3).unwrap().is_none());
    }

    #[test]
    fn builder_failed_cut_keeps_buffer_and_sequence() {
        // A cell that breaks the cut (mismatched shape) must not discard
        // buffered cells or rewind the sequence — positions already handed
        // out would silently re-bind to later data.
        let mut b = ChunkBuilder::new(2, Compression::None);
        b.append(1, vec![Tensor::from_f32(&[2], &[0., 1.]).unwrap()])
            .unwrap();
        let err = b.append(2, vec![Tensor::from_f32(&[3], &[0., 1., 2.]).unwrap()]);
        assert!(err.is_err(), "mismatched shapes cannot stack");
        assert_eq!(b.buffered_steps(), 2, "buffer intact after failed cut");
        assert_eq!(b.next_sequence(), 2, "sequence not rewound");
        // The bad cell keeps the cut failing loudly; reset recovers.
        assert!(b.flush(3).is_err());
        b.reset();
        assert!(b.append(4, step(&[0., 1.], 0)).unwrap().is_none());
    }

    #[test]
    fn builder_reset_clears_sequence() {
        let mut b = ChunkBuilder::new(2, Compression::None);
        b.append(1, step(&[0.], 0)).unwrap();
        b.reset();
        assert_eq!(b.buffered_steps(), 0);
        assert_eq!(b.next_sequence(), 0);
    }

    #[test]
    fn validate_signature_checks_columns() {
        let steps = vec![step(&[1., 2.], 0)];
        let chunk = Chunk::from_steps(1, 0, &steps, Compression::None).unwrap();
        let good = Signature::new(vec![
            TensorSpec::new("obs", &[2], DType::F32),
            TensorSpec::new("act", &[], DType::I32),
        ]);
        chunk.validate_signature(&good).unwrap();
        let bad = Signature::new(vec![
            TensorSpec::new("obs", &[3], DType::F32),
            TensorSpec::new("act", &[], DType::I32),
        ]);
        assert!(chunk.validate_signature(&bad).is_err());
        let bad_dtype = Signature::new(vec![
            TensorSpec::new("obs", &[2], DType::F64),
            TensorSpec::new("act", &[], DType::I32),
        ]);
        assert!(chunk.validate_signature(&bad_dtype).is_err());
    }
}
