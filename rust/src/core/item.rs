//! Items (§3.2): the unit of sampling. An item references a span of steps
//! across one or more chunks (Fig. 3) and carries a mutable priority.

use crate::core::chunk::Chunk;
use crate::core::tensor::Tensor;
use crate::error::{Error, Result};
use std::sync::Arc;

/// An item held by a [`crate::core::table::Table`].
#[derive(Clone, Debug)]
pub struct Item {
    /// Unique key (client generated).
    pub key: u64,
    /// Name of the owning table (items are per-table; the same underlying
    /// chunks may be referenced by items in several tables).
    pub table: String,
    /// Priority used by Selectors. Clients can update this value.
    pub priority: f64,
    /// Referenced chunks, in stream order. The `Arc`s are the reference
    /// counts tracked by the ChunkStore design.
    pub chunks: Vec<Arc<Chunk>>,
    /// Offset of the item's first step within `chunks[0]`.
    pub offset: usize,
    /// Total number of steps spanned by the item.
    pub length: usize,
    /// How many times this item has been sampled so far.
    pub times_sampled: u32,
}

impl Item {
    /// Construct and validate an item over a chunk span.
    pub fn new(
        key: u64,
        table: impl Into<String>,
        priority: f64,
        chunks: Vec<Arc<Chunk>>,
        offset: usize,
        length: usize,
    ) -> Result<Item> {
        if chunks.is_empty() {
            return Err(Error::InvalidArgument("item with no chunks".into()));
        }
        if length == 0 {
            return Err(Error::InvalidArgument("item of zero length".into()));
        }
        if !priority.is_finite() || priority < 0.0 {
            return Err(Error::InvalidArgument(format!(
                "priority must be finite and >= 0, got {priority}"
            )));
        }
        let total: usize = chunks.iter().map(|c| c.num_steps).sum();
        if offset >= chunks[0].num_steps {
            return Err(Error::InvalidArgument(format!(
                "offset {offset} outside first chunk ({} steps)",
                chunks[0].num_steps
            )));
        }
        if offset + length > total {
            return Err(Error::InvalidArgument(format!(
                "item span [{offset}, {}) exceeds {total} chunked steps",
                offset + length
            )));
        }
        // Chunks must be sequential within one stream.
        for w in chunks.windows(2) {
            let (a, b) = (&w[0], &w[1]);
            if a.sequence_start + a.num_steps as u64 != b.sequence_start {
                return Err(Error::InvalidArgument(format!(
                    "non-contiguous chunks: [{}, {}) then [{}, ...)",
                    a.sequence_start,
                    a.sequence_start + a.num_steps as u64,
                    b.sequence_start
                )));
            }
        }
        Ok(Item {
            key,
            table: table.into(),
            priority,
            chunks,
            offset,
            length,
            times_sampled: 0,
        })
    }

    /// Total *encoded* payload bytes across the referenced chunks. Note the
    /// §3.2 overhead discussion: all referenced chunk bytes travel on
    /// sampling even when offset/length select a sub-span.
    pub fn referenced_bytes(&self) -> usize {
        self.chunks.iter().map(|c| c.encoded_len()).sum()
    }

    /// Decode exactly the steps this item spans: one tensor per signature
    /// field, each with leading axis `length`. Performed entirely outside
    /// table locks (the caller holds `Arc<Chunk>`s).
    pub fn materialize(&self) -> Result<Vec<Tensor>> {
        // Fast path: single chunk.
        if self.chunks.len() == 1 {
            return self.chunks[0].decode_rows(self.offset, self.length);
        }
        // Multi-chunk: decode each chunk's contribution, then concatenate
        // along the time axis per field.
        let num_fields = self.chunks[0].columns.len();
        let mut per_field: Vec<Vec<Tensor>> = vec![Vec::new(); num_fields];
        let mut remaining = self.length;
        let mut offset = self.offset;
        for chunk in &self.chunks {
            if remaining == 0 {
                break;
            }
            let take = (chunk.num_steps - offset).min(remaining);
            let rows = chunk.decode_rows(offset, take)?;
            if rows.len() != num_fields {
                return Err(Error::Decode(
                    "inconsistent field count across item chunks".into(),
                ));
            }
            for (f, t) in rows.into_iter().enumerate() {
                per_field[f].push(t);
            }
            remaining -= take;
            offset = 0;
        }
        if remaining > 0 {
            return Err(Error::Decode("item spans more steps than chunks hold".into()));
        }
        per_field
            .into_iter()
            .map(|parts| concat_rows(&parts))
            .collect()
    }
}

/// Concatenate tensors along the leading axis.
fn concat_rows(parts: &[Tensor]) -> Result<Tensor> {
    let first = parts
        .first()
        .ok_or_else(|| Error::InvalidArgument("concat of zero tensors".into()))?;
    if parts.len() == 1 {
        return Ok(first.clone());
    }
    let inner = &first.shape()[1..];
    let mut rows = 0;
    let mut data = Vec::new();
    for p in parts {
        if &p.shape()[1..] != inner || p.dtype() != first.dtype() {
            return Err(Error::SignatureMismatch(
                "concat parts disagree on inner shape/dtype".into(),
            ));
        }
        rows += p.shape()[0];
        data.extend_from_slice(p.bytes());
    }
    let mut shape = vec![rows];
    shape.extend_from_slice(inner);
    Tensor::from_bytes(first.dtype(), shape, data)
}

/// A sampled item as returned to clients: the item metadata plus sampling
/// info (the table also reports the sampling probability when the sampler
/// defines one).
#[derive(Clone, Debug)]
pub struct SampledItem {
    pub item: Item,
    /// Probability with which the sampler chose this item (1.0 for
    /// deterministic selectors).
    pub probability: f64,
    /// Table size at the moment of sampling (for importance weights).
    pub table_size: usize,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::chunk::{Chunk, Compression};

    fn chunk(key: u64, start: u64, vals: &[f32]) -> Arc<Chunk> {
        let steps: Vec<Vec<Tensor>> = vals
            .iter()
            .map(|&v| vec![Tensor::from_f32(&[1], &[v]).unwrap()])
            .collect();
        Arc::new(Chunk::from_steps(key, start, &steps, Compression::None).unwrap())
    }

    #[test]
    fn item_validation() {
        let c = chunk(1, 0, &[0., 1., 2., 3.]);
        assert!(Item::new(1, "t", 1.0, vec![c.clone()], 0, 4).is_ok());
        assert!(Item::new(1, "t", 1.0, vec![c.clone()], 1, 3).is_ok());
        assert!(Item::new(1, "t", 1.0, vec![c.clone()], 1, 4).is_err()); // overruns
        assert!(Item::new(1, "t", 1.0, vec![c.clone()], 4, 1).is_err()); // offset oob
        assert!(Item::new(1, "t", 1.0, vec![], 0, 1).is_err());
        assert!(Item::new(1, "t", 1.0, vec![c.clone()], 0, 0).is_err());
        assert!(Item::new(1, "t", f64::NAN, vec![c.clone()], 0, 1).is_err());
        assert!(Item::new(1, "t", -1.0, vec![c], 0, 1).is_err());
    }

    #[test]
    fn rejects_non_contiguous_chunks() {
        let a = chunk(1, 0, &[0., 1.]);
        let gap = chunk(2, 5, &[5., 6.]);
        assert!(Item::new(1, "t", 1.0, vec![a, gap], 0, 3).is_err());
    }

    #[test]
    fn materialize_single_chunk() {
        let c = chunk(1, 0, &[0., 1., 2., 3.]);
        let item = Item::new(1, "t", 1.0, vec![c], 1, 2).unwrap();
        let out = item.materialize().unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].shape(), &[2, 1]);
        assert_eq!(out[0].to_f32().unwrap(), vec![1., 2.]);
    }

    #[test]
    fn materialize_across_chunks() {
        let a = chunk(1, 0, &[0., 1., 2.]);
        let b = chunk(2, 3, &[3., 4., 5.]);
        // Span steps 2..5 (last of a, first two of b).
        let item = Item::new(9, "t", 1.0, vec![a, b], 2, 3).unwrap();
        let out = item.materialize().unwrap();
        assert_eq!(out[0].shape(), &[3, 1]);
        assert_eq!(out[0].to_f32().unwrap(), vec![2., 3., 4.]);
    }

    #[test]
    fn referenced_bytes_counts_whole_chunks() {
        let a = chunk(1, 0, &[0., 1., 2.]);
        let b = chunk(2, 3, &[3., 4., 5.]);
        let total = a.encoded_len() + b.encoded_len();
        let item = Item::new(9, "t", 1.0, vec![a, b], 2, 2).unwrap();
        // Even though only 2 steps are used, both chunks are "sent" (§3.2).
        assert_eq!(item.referenced_bytes(), total);
    }
}
