//! Items (§3.2): the unit of sampling. An item references stored steps and
//! carries a mutable priority. Two representations coexist (DESIGN.md §9):
//!
//! - **Flat** (the paper's Fig. 3): a contiguous span of whole steps across
//!   one or more multi-column chunks, described by `(chunks, offset,
//!   length)`. Produced by the legacy trailing-window `Writer`.
//! - **Trajectory** (§3.8 "flexible API"): per-column lists of chunk-slice
//!   ranges — each column gathers its own (possibly non-contiguous) rows
//!   from single-column chunks and may be squeezed to drop the time axis.
//!   Produced by `TrajectoryWriter`.

use crate::core::chunk::Chunk;
use crate::core::chunk_store::ChunkHandle;
use crate::core::tensor::Tensor;
use crate::error::{Error, Result};
use std::collections::HashMap;
use std::sync::Arc;

/// One contiguous run of rows inside a single chunk, referenced by a
/// trajectory column. Chunks are addressed by key: the owning [`Item`]
/// carries the [`ChunkHandle`]s in [`Item::chunks`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChunkSlice {
    /// Key of the referenced chunk.
    pub chunk_key: u64,
    /// First row of the run within the chunk.
    pub offset: usize,
    /// Number of rows in the run (>= 1).
    pub length: usize,
}

/// One named column of a trajectory item: an ordered gather of chunk-slice
/// runs. Non-adjacent runs express strided / non-contiguous trajectories
/// (e.g. n-step returns that skip steps).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TrajectoryColumn {
    /// Column name as written by the client (`TrajectoryWriter` column).
    pub name: String,
    /// Slice runs, gathered in order along the time axis.
    pub slices: Vec<ChunkSlice>,
    /// Materialize without the leading time axis (requires exactly one
    /// referenced row total).
    pub squeeze: bool,
}

impl TrajectoryColumn {
    /// Total rows gathered by this column.
    pub fn num_steps(&self) -> usize {
        self.slices.iter().map(|s| s.length).sum()
    }

    /// Serialize an optional column list: a presence byte, then per column
    /// its name, squeeze flag, and `(chunk_key, offset, length)` runs.
    /// Shared by the wire protocol (v2 item frames), the checkpoint format,
    /// and the persist journal (like [`Chunk::encode`]), so the layouts
    /// cannot drift.
    pub fn encode_list<W: std::io::Write>(
        cols: Option<&[TrajectoryColumn]>,
        w: &mut W,
    ) -> Result<()> {
        use crate::io::*;
        match cols {
            None => put_u8(w, 0)?,
            Some(cols) => {
                put_u8(w, 1)?;
                put_u32(w, cols.len() as u32)?;
                for col in cols {
                    put_string(w, &col.name)?;
                    put_u8(w, col.squeeze as u8)?;
                    put_u32(w, col.slices.len() as u32)?;
                    for s in &col.slices {
                        put_u64(w, s.chunk_key)?;
                        put_u64(w, s.offset as u64)?;
                        put_u64(w, s.length as u64)?;
                    }
                }
            }
        }
        Ok(())
    }

    /// Inverse of [`TrajectoryColumn::encode_list`].
    pub fn decode_list<R: std::io::Read>(r: &mut R) -> Result<Option<Vec<TrajectoryColumn>>> {
        use crate::io::*;
        if get_u8(r)? == 0 {
            return Ok(None);
        }
        let ncols = get_u32(r)? as usize;
        if ncols > 4096 {
            return Err(Error::Decode(format!("{ncols} item columns exceeds limit")));
        }
        let mut cols = Vec::with_capacity(ncols);
        for _ in 0..ncols {
            let name = get_string(r)?;
            let squeeze = get_u8(r)? != 0;
            let nslices = get_u32(r)? as usize;
            if nslices > 1 << 20 {
                return Err(Error::Decode(format!("{nslices} slices exceeds limit")));
            }
            let slices = (0..nslices)
                .map(|_| {
                    Ok(ChunkSlice {
                        chunk_key: get_u64(r)?,
                        offset: get_u64(r)? as usize,
                        length: get_u64(r)? as usize,
                    })
                })
                .collect::<Result<_>>()?;
            cols.push(TrajectoryColumn {
                name,
                squeeze,
                slices,
            });
        }
        Ok(Some(cols))
    }
}

/// An item held by a [`crate::core::table::Table`].
#[derive(Clone, Debug)]
pub struct Item {
    /// Unique key (client generated).
    pub key: u64,
    /// Name of the owning table (items are per-table; the same underlying
    /// chunks may be referenced by items in several tables).
    pub table: String,
    /// Priority used by Selectors. Clients can update this value.
    pub priority: f64,
    /// Referenced chunks, in stream order, as tier-agnostic handles: the
    /// shared slots are the reference counts tracked by the ChunkStore
    /// design, whether the payload is hot in memory or spilled cold. For
    /// trajectory items this is the deduplicated union of every column's
    /// referenced chunks.
    pub chunks: Vec<ChunkHandle>,
    /// Offset of the item's first step within `chunks[0]` (flat items; 0
    /// for trajectory items).
    pub offset: usize,
    /// Total number of steps spanned by the item (flat items), or the
    /// longest column's row count (trajectory items) — the value extension
    /// step counters see either way.
    pub length: usize,
    /// How many times this item has been sampled so far.
    pub times_sampled: u32,
    /// Per-column gather lists: `None` for flat items, `Some` for
    /// trajectory items. Shared behind an `Arc` so the per-sample item
    /// clone (`sampled_to_wire`/`materialize_sample`) copies a pointer, not
    /// the column metadata, on the sampling hot path.
    pub columns: Option<Arc<Vec<TrajectoryColumn>>>,
}

fn validate_priority(priority: f64) -> Result<()> {
    if !priority.is_finite() || priority < 0.0 {
        return Err(Error::InvalidArgument(format!(
            "priority must be finite and >= 0, got {priority}"
        )));
    }
    Ok(())
}

impl Item {
    /// Construct and validate an item over a chunk span. Accepts anything
    /// convertible to [`ChunkHandle`] — store handles on the server path,
    /// plain `Arc<Chunk>`s (wrapped detached) on the client and in tests.
    /// Validation reads only slot metadata, so cold chunks stay cold.
    pub fn new<H: Into<ChunkHandle>>(
        key: u64,
        table: impl Into<String>,
        priority: f64,
        chunks: Vec<H>,
        offset: usize,
        length: usize,
    ) -> Result<Item> {
        let chunks: Vec<ChunkHandle> = chunks.into_iter().map(Into::into).collect();
        if chunks.is_empty() {
            return Err(Error::InvalidArgument("item with no chunks".into()));
        }
        if length == 0 {
            return Err(Error::InvalidArgument("item of zero length".into()));
        }
        validate_priority(priority)?;
        let total: usize = chunks.iter().map(|c| c.num_steps).sum();
        if offset >= chunks[0].num_steps {
            return Err(Error::InvalidArgument(format!(
                "offset {offset} outside first chunk ({} steps)",
                chunks[0].num_steps
            )));
        }
        if offset + length > total {
            return Err(Error::InvalidArgument(format!(
                "item span [{offset}, {}) exceeds {total} chunked steps",
                offset + length
            )));
        }
        // Chunks must be sequential within one stream.
        for w in chunks.windows(2) {
            let (a, b) = (&w[0], &w[1]);
            if a.sequence_start + a.num_steps as u64 != b.sequence_start {
                return Err(Error::InvalidArgument(format!(
                    "non-contiguous chunks: [{}, {}) then [{}, ...)",
                    a.sequence_start,
                    a.sequence_start + a.num_steps as u64,
                    b.sequence_start
                )));
            }
        }
        Ok(Item {
            key,
            table: table.into(),
            priority,
            chunks,
            offset,
            length,
            times_sampled: 0,
            columns: None,
        })
    }

    /// Construct and validate a trajectory item: per-column gather lists
    /// over single-column chunks. `chunks` must be exactly the
    /// deduplicated set of chunks the slices reference (this is what the
    /// server's insert path checks the wire item against).
    pub fn new_trajectory<H: Into<ChunkHandle>>(
        key: u64,
        table: impl Into<String>,
        priority: f64,
        chunks: Vec<H>,
        columns: Vec<TrajectoryColumn>,
    ) -> Result<Item> {
        Self::new_trajectory_shared(key, table, priority, chunks, Arc::new(columns))
    }

    /// Like [`Item::new_trajectory`], but sharing an already-built column
    /// list. The wire and checkpoint paths pass their decoded `Arc` through
    /// so re-validation never clones the column metadata.
    pub fn new_trajectory_shared<H: Into<ChunkHandle>>(
        key: u64,
        table: impl Into<String>,
        priority: f64,
        chunks: Vec<H>,
        columns: Arc<Vec<TrajectoryColumn>>,
    ) -> Result<Item> {
        let chunks: Vec<ChunkHandle> = chunks.into_iter().map(Into::into).collect();
        if chunks.is_empty() {
            return Err(Error::InvalidArgument("item with no chunks".into()));
        }
        if columns.is_empty() {
            return Err(Error::InvalidArgument(
                "trajectory item with no columns".into(),
            ));
        }
        validate_priority(priority)?;
        let mut by_key: HashMap<u64, &ChunkHandle> = HashMap::with_capacity(chunks.len());
        for c in &chunks {
            if by_key.insert(c.key, c).is_some() {
                return Err(Error::InvalidArgument(format!(
                    "duplicate chunk {} in trajectory item",
                    c.key
                )));
            }
        }
        let mut referenced: std::collections::HashSet<u64> = std::collections::HashSet::new();
        let mut length = 0usize;
        for col in columns.iter() {
            if col.slices.is_empty() {
                return Err(Error::InvalidArgument(format!(
                    "trajectory column {:?} has no chunk slices",
                    col.name
                )));
            }
            let mut steps = 0usize;
            for s in &col.slices {
                if s.length == 0 {
                    return Err(Error::InvalidArgument(format!(
                        "trajectory column {:?}: zero-length chunk slice",
                        col.name
                    )));
                }
                let chunk = by_key
                    .get(&s.chunk_key)
                    .ok_or(Error::ChunkNotFound(s.chunk_key))?;
                if chunk.num_columns != 1 {
                    return Err(Error::SignatureMismatch(format!(
                        "trajectory column {:?} references chunk {} with {} fields \
                         (trajectory chunks hold exactly one column)",
                        col.name, s.chunk_key, chunk.num_columns
                    )));
                }
                if s.offset + s.length > chunk.num_steps {
                    return Err(Error::InvalidArgument(format!(
                        "trajectory column {:?}: slice [{}, {}) exceeds chunk {} ({} steps)",
                        col.name,
                        s.offset,
                        s.offset + s.length,
                        s.chunk_key,
                        chunk.num_steps
                    )));
                }
                referenced.insert(s.chunk_key);
                steps += s.length;
            }
            if col.squeeze && steps != 1 {
                return Err(Error::InvalidArgument(format!(
                    "squeezed column {:?} references {steps} steps (must be 1)",
                    col.name
                )));
            }
            length = length.max(steps);
        }
        if referenced.len() != chunks.len() {
            return Err(Error::InvalidArgument(format!(
                "trajectory item carries {} chunks but references {}",
                chunks.len(),
                referenced.len()
            )));
        }
        Ok(Item {
            key,
            table: table.into(),
            priority,
            chunks,
            offset: 0,
            length,
            times_sampled: 0,
            columns: Some(columns),
        })
    }

    /// The trajectory column list as a slice, if this is a trajectory item
    /// (the borrow encoders want, without exposing the `Arc`).
    pub fn columns_slice(&self) -> Option<&[TrajectoryColumn]> {
        self.columns.as_deref().map(|v| v.as_slice())
    }

    /// Total *encoded* payload bytes across the referenced chunks. Note the
    /// §3.2 overhead discussion: all referenced chunk bytes travel on
    /// sampling even when offset/length select a sub-span.
    pub fn referenced_bytes(&self) -> usize {
        self.chunks.iter().map(|c| c.encoded_len()).sum()
    }

    /// Decode the data this item references: one tensor per field/column,
    /// in order. Flat items yield one tensor per signature field with
    /// leading axis `length`; trajectory items yield one tensor per column
    /// with a per-column leading axis (absent when squeezed). Performed
    /// entirely outside table locks (the caller holds `Arc<Chunk>`s).
    pub fn materialize(&self) -> Result<Vec<Tensor>> {
        if let Some(cols) = &self.columns {
            return Ok(self
                .materialize_trajectory(cols.as_slice())?
                .into_iter()
                .map(|(_, t)| t)
                .collect());
        }
        self.materialize_flat()
    }

    /// Like [`Item::materialize`], but with column names attached:
    /// trajectory items use their writer-side column names, flat items the
    /// positional `field_{i}` names of [`crate::core::tensor::Signature`].
    pub fn materialize_columns(&self) -> Result<Vec<(String, Tensor)>> {
        if let Some(cols) = &self.columns {
            return self.materialize_trajectory(cols.as_slice());
        }
        Ok(self
            .materialize_flat()?
            .into_iter()
            .enumerate()
            .map(|(i, t)| (format!("field_{i}"), t))
            .collect())
    }

    /// Per-column gather: decode each slice run from its (single-column)
    /// chunk, concatenate along the time axis, squeeze if requested.
    /// Resolves each referenced chunk once up front (rehydrating cold
    /// ones), so repeated slices into one chunk share the decode.
    fn materialize_trajectory(
        &self,
        cols: &[TrajectoryColumn],
    ) -> Result<Vec<(String, Tensor)>> {
        let mut by_key: HashMap<u64, Arc<Chunk>> = HashMap::with_capacity(self.chunks.len());
        for c in &self.chunks {
            by_key.insert(c.key, c.resolve()?);
        }
        let mut out = Vec::with_capacity(cols.len());
        for col in cols {
            let mut parts = Vec::with_capacity(col.slices.len());
            for s in &col.slices {
                let chunk = by_key
                    .get(&s.chunk_key)
                    .ok_or(Error::ChunkNotFound(s.chunk_key))?;
                let mut rows = chunk.decode_rows(s.offset, s.length)?;
                if rows.len() != 1 {
                    return Err(Error::Decode(format!(
                        "trajectory chunk {} decoded to {} fields, expected 1",
                        s.chunk_key,
                        rows.len()
                    )));
                }
                parts.push(rows.pop().expect("one field"));
            }
            let stacked = concat_rows(&parts)?;
            let tensor = if col.squeeze {
                stacked.squeeze_leading()?
            } else {
                stacked
            };
            out.push((col.name.clone(), tensor));
        }
        Ok(out)
    }

    /// Flat-span decoding (the legacy representation).
    fn materialize_flat(&self) -> Result<Vec<Tensor>> {
        // Fast path: single chunk.
        if self.chunks.len() == 1 {
            return self.chunks[0].resolve()?.decode_rows(self.offset, self.length);
        }
        // Multi-chunk: decode each chunk's contribution, then concatenate
        // along the time axis per field.
        let num_fields = self.chunks[0].num_columns;
        let mut per_field: Vec<Vec<Tensor>> = vec![Vec::new(); num_fields];
        let mut remaining = self.length;
        let mut offset = self.offset;
        for chunk in &self.chunks {
            if remaining == 0 {
                break;
            }
            let take = (chunk.num_steps - offset).min(remaining);
            let rows = chunk.resolve()?.decode_rows(offset, take)?;
            if rows.len() != num_fields {
                return Err(Error::Decode(
                    "inconsistent field count across item chunks".into(),
                ));
            }
            for (f, t) in rows.into_iter().enumerate() {
                per_field[f].push(t);
            }
            remaining -= take;
            offset = 0;
        }
        if remaining > 0 {
            return Err(Error::Decode("item spans more steps than chunks hold".into()));
        }
        per_field
            .into_iter()
            .map(|parts| concat_rows(&parts))
            .collect()
    }
}

/// Concatenate tensors along the leading axis.
fn concat_rows(parts: &[Tensor]) -> Result<Tensor> {
    let first = parts
        .first()
        .ok_or_else(|| Error::InvalidArgument("concat of zero tensors".into()))?;
    if parts.len() == 1 {
        return Ok(first.clone());
    }
    let inner = &first.shape()[1..];
    let mut rows = 0;
    let mut data = Vec::new();
    for p in parts {
        if &p.shape()[1..] != inner || p.dtype() != first.dtype() {
            return Err(Error::SignatureMismatch(
                "concat parts disagree on inner shape/dtype".into(),
            ));
        }
        rows += p.shape()[0];
        data.extend_from_slice(p.bytes());
    }
    let mut shape = vec![rows];
    shape.extend_from_slice(inner);
    Tensor::from_bytes(first.dtype(), shape, data)
}

/// A sampled item as returned to clients: the item metadata plus sampling
/// info (the table also reports the sampling probability when the sampler
/// defines one).
#[derive(Clone, Debug)]
pub struct SampledItem {
    pub item: Item,
    /// Probability with which the sampler chose this item (1.0 for
    /// deterministic selectors).
    pub probability: f64,
    /// Table size at the moment of sampling (for importance weights).
    pub table_size: usize,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::chunk::{Chunk, Compression};

    fn chunk(key: u64, start: u64, vals: &[f32]) -> Arc<Chunk> {
        let steps: Vec<Vec<Tensor>> = vals
            .iter()
            .map(|&v| vec![Tensor::from_f32(&[1], &[v]).unwrap()])
            .collect();
        Arc::new(Chunk::from_steps(key, start, &steps, Compression::None).unwrap())
    }

    #[test]
    fn item_validation() {
        let c = chunk(1, 0, &[0., 1., 2., 3.]);
        assert!(Item::new(1, "t", 1.0, vec![c.clone()], 0, 4).is_ok());
        assert!(Item::new(1, "t", 1.0, vec![c.clone()], 1, 3).is_ok());
        assert!(Item::new(1, "t", 1.0, vec![c.clone()], 1, 4).is_err()); // overruns
        assert!(Item::new(1, "t", 1.0, vec![c.clone()], 4, 1).is_err()); // offset oob
        assert!(Item::new(1, "t", 1.0, Vec::<Arc<Chunk>>::new(), 0, 1).is_err());
        assert!(Item::new(1, "t", 1.0, vec![c.clone()], 0, 0).is_err());
        assert!(Item::new(1, "t", f64::NAN, vec![c.clone()], 0, 1).is_err());
        assert!(Item::new(1, "t", -1.0, vec![c], 0, 1).is_err());
    }

    #[test]
    fn rejects_non_contiguous_chunks() {
        let a = chunk(1, 0, &[0., 1.]);
        let gap = chunk(2, 5, &[5., 6.]);
        assert!(Item::new(1, "t", 1.0, vec![a, gap], 0, 3).is_err());
    }

    #[test]
    fn materialize_single_chunk() {
        let c = chunk(1, 0, &[0., 1., 2., 3.]);
        let item = Item::new(1, "t", 1.0, vec![c], 1, 2).unwrap();
        let out = item.materialize().unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].shape(), &[2, 1]);
        assert_eq!(out[0].to_f32().unwrap(), vec![1., 2.]);
    }

    #[test]
    fn materialize_across_chunks() {
        let a = chunk(1, 0, &[0., 1., 2.]);
        let b = chunk(2, 3, &[3., 4., 5.]);
        // Span steps 2..5 (last of a, first two of b).
        let item = Item::new(9, "t", 1.0, vec![a, b], 2, 3).unwrap();
        let out = item.materialize().unwrap();
        assert_eq!(out[0].shape(), &[3, 1]);
        assert_eq!(out[0].to_f32().unwrap(), vec![2., 3., 4.]);
    }

    fn slice(chunk_key: u64, offset: usize, length: usize) -> ChunkSlice {
        ChunkSlice {
            chunk_key,
            offset,
            length,
        }
    }

    fn col(name: &str, slices: Vec<ChunkSlice>, squeeze: bool) -> TrajectoryColumn {
        TrajectoryColumn {
            name: name.into(),
            slices,
            squeeze,
        }
    }

    #[test]
    fn trajectory_validation() {
        let a = chunk(1, 0, &[0., 1., 2., 3.]);
        let b = chunk(2, 0, &[10., 11.]);
        let ok = Item::new_trajectory(
            9,
            "t",
            1.0,
            vec![a.clone(), b.clone()],
            vec![
                col("obs", vec![slice(1, 0, 4)], false),
                col("r", vec![slice(2, 0, 2)], false),
            ],
        );
        assert!(ok.is_ok());
        let item = ok.unwrap();
        assert_eq!(item.length, 4, "length is the longest column");
        assert_eq!(item.offset, 0);
        // No columns / no slices / zero-length slice.
        assert!(Item::new_trajectory(9, "t", 1.0, vec![a.clone()], vec![]).is_err());
        assert!(Item::new_trajectory(
            9,
            "t",
            1.0,
            vec![a.clone()],
            vec![col("obs", vec![], false)]
        )
        .is_err());
        assert!(Item::new_trajectory(
            9,
            "t",
            1.0,
            vec![a.clone()],
            vec![col("obs", vec![slice(1, 0, 0)], false)]
        )
        .is_err());
        // Unknown chunk key.
        assert!(Item::new_trajectory(
            9,
            "t",
            1.0,
            vec![a.clone()],
            vec![col("obs", vec![slice(99, 0, 1)], false)]
        )
        .is_err());
        // Span exceeds the chunk.
        assert!(Item::new_trajectory(
            9,
            "t",
            1.0,
            vec![a.clone()],
            vec![col("obs", vec![slice(1, 3, 2)], false)]
        )
        .is_err());
        // Squeeze over more than one step.
        assert!(Item::new_trajectory(
            9,
            "t",
            1.0,
            vec![a.clone()],
            vec![col("obs", vec![slice(1, 0, 2)], true)]
        )
        .is_err());
        // Carried-but-unreferenced chunk.
        assert!(Item::new_trajectory(
            9,
            "t",
            1.0,
            vec![a.clone(), b.clone()],
            vec![col("obs", vec![slice(1, 0, 4)], false)]
        )
        .is_err());
        // Multi-field chunks cannot back a trajectory column.
        let multi = Arc::new(
            Chunk::from_steps(
                7,
                0,
                &[vec![
                    Tensor::from_f32(&[1], &[0.]).unwrap(),
                    Tensor::from_f32(&[1], &[1.]).unwrap(),
                ]],
                Compression::None,
            )
            .unwrap(),
        );
        assert!(Item::new_trajectory(
            9,
            "t",
            1.0,
            vec![multi],
            vec![col("obs", vec![slice(7, 0, 1)], false)]
        )
        .is_err());
    }

    #[test]
    fn trajectory_materializes_per_column() {
        // Column "obs" gathers a non-contiguous pick (rows 0 and 2-3 of one
        // chunk plus row 1 of another); column "last" squeezes one step.
        let a = chunk(1, 0, &[0., 1., 2., 3.]);
        let b = chunk(2, 4, &[4., 5.]);
        let item = Item::new_trajectory(
            9,
            "t",
            1.0,
            vec![a, b],
            vec![
                col(
                    "obs",
                    vec![slice(1, 0, 1), slice(1, 2, 2), slice(2, 1, 1)],
                    false,
                ),
                col("last", vec![slice(2, 0, 1)], true),
            ],
        )
        .unwrap();
        assert_eq!(item.length, 4);
        let cols = item.materialize_columns().unwrap();
        assert_eq!(cols.len(), 2);
        assert_eq!(cols[0].0, "obs");
        assert_eq!(cols[0].1.shape(), &[4, 1]);
        assert_eq!(cols[0].1.to_f32().unwrap(), vec![0., 2., 3., 5.]);
        assert_eq!(cols[1].0, "last");
        assert_eq!(cols[1].1.shape(), &[1], "squeezed: no time axis");
        assert_eq!(cols[1].1.to_f32().unwrap(), vec![4.]);
        // The flat view matches, names dropped.
        let flat = item.materialize().unwrap();
        assert_eq!(flat[0].to_f32().unwrap(), vec![0., 2., 3., 5.]);
        assert_eq!(flat[1].shape(), &[1]);
    }

    #[test]
    fn column_list_codec_roundtrip() {
        for cols in [
            None,
            Some(vec![
                col("obs", vec![slice(1, 0, 3), slice(2, 4, 2)], false),
                col("act", vec![slice(3, 1, 1)], true),
            ]),
        ] {
            let mut buf = Vec::new();
            TrajectoryColumn::encode_list(cols.as_deref(), &mut buf).unwrap();
            let back =
                TrajectoryColumn::decode_list(&mut std::io::Cursor::new(buf)).unwrap();
            assert_eq!(back, cols);
        }
    }

    #[test]
    fn flat_items_report_positional_column_names() {
        let c = chunk(1, 0, &[0., 1.]);
        let item = Item::new(1, "t", 1.0, vec![c], 0, 2).unwrap();
        let cols = item.materialize_columns().unwrap();
        assert_eq!(cols[0].0, "field_0");
        assert_eq!(cols[0].1.shape(), &[2, 1]);
    }

    #[test]
    fn referenced_bytes_counts_whole_chunks() {
        let a = chunk(1, 0, &[0., 1., 2.]);
        let b = chunk(2, 3, &[3., 4., 5.]);
        let total = a.encoded_len() + b.encoded_len();
        let item = Item::new(9, "t", 1.0, vec![a, b], 2, 2).unwrap();
        // Even though only 2 steps are used, both chunks are "sent" (§3.2).
        assert_eq!(item.referenced_bytes(), total);
    }
}
