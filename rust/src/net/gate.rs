//! Checkpoint gate (§3.7): "During the checkpointing process, the server
//! blocks all incoming insert, sample, update, and delete requests."
//!
//! A pausable in-flight counter: request handlers `enter()` before touching
//! tables and `exit()` after; the checkpointer calls `pause()` which stops
//! new entries and waits for in-flight handlers to drain, then `resume()`.
//! Handlers slice long blocking waits into short segments and re-enter the
//! gate between segments, so a pause never waits on a rate-limiter block.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

#[derive(Default)]
struct GateState {
    paused: bool,
    in_flight: usize,
    /// When the current pause began (measured from `pause()` entry, so the
    /// recorded window includes the in-flight drain wait).
    paused_at: Option<Instant>,
    /// One-shot callbacks fired when the gate reopens — the event-driven
    /// server parks a connection whose `try_enter` failed and re-arms it
    /// from here instead of pinning a worker thread on `enter()`.
    resume_wakers: Vec<Arc<dyn Fn() + Send + Sync>>,
}

/// Pausable entry gate.
#[derive(Default)]
pub struct Gate {
    state: Mutex<GateState>,
    cv: Condvar,
    /// Nanoseconds requests were blocked by the most recent pause/resume
    /// cycle — what `benches/checkpoint_pause.rs` tracks against table
    /// size (DESIGN.md §10).
    last_pause_nanos: AtomicU64,
}

impl Gate {
    pub fn new() -> Self {
        Self::default()
    }

    /// Block until unpaused, then register as in-flight.
    pub fn enter(&self) -> GateGuard<'_> {
        let mut s = self.state.lock().unwrap();
        while s.paused {
            s = self.cv.wait(s).unwrap();
        }
        s.in_flight += 1;
        GateGuard { gate: self }
    }

    /// [`Gate::enter`] that also reports how long entry blocked — the
    /// threaded service model attributes this wait to the `gate` stage
    /// (DESIGN.md §15). The fast path (unpaused) takes no clock reading.
    pub fn enter_timed(&self) -> (GateGuard<'_>, Duration) {
        let mut s = self.state.lock().unwrap();
        let mut waited = Duration::ZERO;
        if s.paused {
            let started = Instant::now();
            while s.paused {
                s = self.cv.wait(s).unwrap();
            }
            waited = started.elapsed();
        }
        s.in_flight += 1;
        (GateGuard { gate: self }, waited)
    }

    /// Try to enter without blocking; `None` when paused.
    pub fn try_enter(&self) -> Option<GateGuard<'_>> {
        let mut s = self.state.lock().unwrap();
        if s.paused {
            return None;
        }
        s.in_flight += 1;
        Some(GateGuard { gate: self })
    }

    fn exit(&self) {
        let mut s = self.state.lock().unwrap();
        s.in_flight -= 1;
        if s.in_flight == 0 {
            self.cv.notify_all();
        }
    }

    /// Stop new entries and wait for all in-flight work to drain.
    pub fn pause(&self) {
        let started = Instant::now();
        let mut s = self.state.lock().unwrap();
        s.paused = true;
        s.paused_at = Some(started);
        while s.in_flight > 0 {
            s = self.cv.wait(s).unwrap();
        }
    }

    /// Allow entries again.
    pub fn resume(&self) {
        let mut s = self.state.lock().unwrap();
        if let Some(started) = s.paused_at.take() {
            self.last_pause_nanos
                .store(started.elapsed().as_nanos() as u64, Ordering::SeqCst);
        }
        s.paused = false;
        let wakers = std::mem::take(&mut s.resume_wakers);
        drop(s);
        self.cv.notify_all();
        for w in wakers {
            w();
        }
    }

    /// Register a one-shot callback fired when the current pause ends. If
    /// the gate is not paused, the callback fires immediately (the
    /// `try_enter` failure it reacts to has already resolved).
    pub fn register_resume_waker(&self, waker: Arc<dyn Fn() + Send + Sync>) {
        let mut s = self.state.lock().unwrap();
        if !s.paused {
            drop(s);
            waker();
            return;
        }
        s.resume_wakers.push(waker);
    }

    /// How long requests were blocked by the most recent pause/resume
    /// cycle (zero before the first pause).
    pub fn last_pause(&self) -> Duration {
        Duration::from_nanos(self.last_pause_nanos.load(Ordering::SeqCst))
    }

    /// Current number of in-flight handlers (diagnostics).
    pub fn in_flight(&self) -> usize {
        self.state.lock().unwrap().in_flight
    }
}

/// RAII in-flight registration.
pub struct GateGuard<'a> {
    gate: &'a Gate,
}

impl Drop for GateGuard<'_> {
    fn drop(&mut self) {
        self.gate.exit();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;
    use std::time::Duration;

    #[test]
    fn enter_exit_counts() {
        let g = Gate::new();
        assert_eq!(g.in_flight(), 0);
        let a = g.enter();
        let b = g.enter();
        assert_eq!(g.in_flight(), 2);
        drop(a);
        assert_eq!(g.in_flight(), 1);
        drop(b);
        assert_eq!(g.in_flight(), 0);
    }

    #[test]
    fn pause_blocks_new_entries_and_drains() {
        let g = Arc::new(Gate::new());
        let counter = Arc::new(AtomicUsize::new(0));

        // A long-ish handler.
        let g2 = g.clone();
        let c2 = counter.clone();
        let worker = std::thread::spawn(move || {
            let _guard = g2.enter();
            std::thread::sleep(Duration::from_millis(50));
            c2.fetch_add(1, Ordering::SeqCst);
        });
        std::thread::sleep(Duration::from_millis(10));

        // pause() must wait for the worker to finish.
        g.pause();
        assert_eq!(counter.load(Ordering::SeqCst), 1, "pause drained in-flight");

        // New entries blocked while paused.
        assert!(g.try_enter().is_none());
        let g3 = g.clone();
        let c3 = counter.clone();
        let blocked = std::thread::spawn(move || {
            let _guard = g3.enter();
            c3.fetch_add(10, Ordering::SeqCst);
        });
        std::thread::sleep(Duration::from_millis(30));
        assert_eq!(counter.load(Ordering::SeqCst), 1, "entry blocked during pause");

        g.resume();
        blocked.join().unwrap();
        assert_eq!(counter.load(Ordering::SeqCst), 11);
        worker.join().unwrap();
    }

    #[test]
    fn try_enter_succeeds_when_unpaused() {
        let g = Gate::new();
        assert!(g.try_enter().is_some());
    }

    #[test]
    fn enter_timed_reports_pause_wait() {
        let g = Arc::new(Gate::new());
        // Unpaused: no measurable wait.
        let (guard, waited) = g.enter_timed();
        assert_eq!(waited, Duration::ZERO);
        drop(guard);

        g.pause();
        let g2 = g.clone();
        let t = std::thread::spawn(move || {
            let (guard, waited) = g2.enter_timed();
            drop(guard);
            waited
        });
        std::thread::sleep(Duration::from_millis(25));
        g.resume();
        let waited = t.join().unwrap();
        assert!(waited >= Duration::from_millis(15), "{waited:?}");
    }

    #[test]
    fn resume_waker_fires_on_resume_or_immediately() {
        let g = Gate::new();
        let hits = Arc::new(AtomicUsize::new(0));

        // Unpaused: fires synchronously.
        let h = hits.clone();
        g.register_resume_waker(Arc::new(move || {
            h.fetch_add(1, Ordering::SeqCst);
        }));
        assert_eq!(hits.load(Ordering::SeqCst), 1);

        // Paused: held until resume, then fired exactly once.
        g.pause();
        assert!(g.try_enter().is_none());
        let h = hits.clone();
        g.register_resume_waker(Arc::new(move || {
            h.fetch_add(10, Ordering::SeqCst);
        }));
        assert_eq!(hits.load(Ordering::SeqCst), 1, "held while paused");
        g.resume();
        assert_eq!(hits.load(Ordering::SeqCst), 11, "fired on resume");
        g.pause();
        g.resume();
        assert_eq!(hits.load(Ordering::SeqCst), 11, "one-shot: not re-fired");
    }

    #[test]
    fn pause_window_is_recorded() {
        let g = Gate::new();
        assert_eq!(g.last_pause(), Duration::ZERO);
        g.pause();
        std::thread::sleep(Duration::from_millis(20));
        g.resume();
        assert!(g.last_pause() >= Duration::from_millis(20));
        assert!(g.try_enter().is_some(), "gate reopened");
    }
}
