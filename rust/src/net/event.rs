//! The event-driven service core (DESIGN.md §11): `N = service_threads`
//! workers drive per-connection state machines over readiness signals,
//! decoupling connection count from OS-thread count — the prerequisite for
//! the paper's "thousands of concurrent clients" serving claim.
//!
//! # Architecture
//!
//! - **Poller thread** — sleeps in `ppoll(2)` ([`crate::net::poller`])
//!   over every fd-backed connection with an armed interest, plus a timer
//!   heap for parked-operation deadlines and retry slices. Readiness or a
//!   due timer *schedules* the connection onto the ready queue.
//! - **Worker pool** — `N` threads pop scheduled connections and run each
//!   connection's state machine: retry a parked op, resume a partial
//!   write, read frames (`try_recv`, resumable mid-frame), dispatch, and
//!   flush (`try_flush`, resumable mid-write).
//! - **Parked operations** — a `CreateItem`/`SampleRequest` whose rate
//!   limiter (or the checkpoint gate) refuses does NOT pin a worker: the
//!   connection parks with the op, registers a one-shot waker on the
//!   table's waiter lists ([`Table::register_insert_waker`] /
//!   [`Table::register_sample_waker`]) or the gate's resume hook, arms a
//!   bounded retry timer, and the worker moves on. The table's existing
//!   condvar wakeup paths fire the hooks, so corridor wakeups re-arm
//!   connections with the same precision the blocking path enjoys.
//!
//! Per-connection FIFO semantics are preserved by construction: while an
//! op is parked the connection reads no further input (the kernel socket
//! buffer / bounded in-proc channel provides the same client-side
//! backpressure the blocked service thread used to), and replies are
//! written in dispatch order.
//!
//! In-proc connections have no fd; their readiness rides the channel
//! occupancy wakers ([`MsgStream::set_ready_waker`]) instead of the
//! poller.

use crate::core::item::Item;
use crate::core::table::{Table, TryInsertOutcome, TrySampleOutcome};
use crate::error::{Error, Result};
use crate::net::poller::Poller;
use crate::net::server::{batch_too_large, resolve_item, sample_reply, stash_chunks, ServerInner};
use crate::net::trace::{self, ReqSpans, Stage, TraceContext};
use crate::net::transport::{MsgStream, PollSource};
use crate::net::wire::{error_code, BatchResult, Message, WireItem, MAX_BATCH_OPS};
use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::{Duration, Instant};

/// Upper bound on frames handled in one service pass, so one firehose
/// connection cannot starve the others (it re-schedules itself instead).
const MAX_FRAMES_PER_SERVICE: usize = 128;

/// Retry slice for limiter-parked ops: the waker is the fast path; the
/// timer bounds staleness exactly like the blocking path's `WAIT_SLICE`.
const PARK_SLICE: Duration = Duration::from_millis(50);

/// Retry slice for gate-parked ops (checkpoint pauses are short).
const GATE_SLICE: Duration = Duration::from_millis(2);

/// Poller tick when no timer is due sooner.
const POLL_TICK: Duration = Duration::from_millis(25);

/// Cap on client-supplied op timeouts: practically infinite, while
/// keeping `Instant + timeout` arithmetic overflow-free for adversarial
/// `timeout_ms` values (a worker must never panic on wire input).
const MAX_OP_TIMEOUT: Duration = Duration::from_secs(30 * 24 * 3600);

/// Default worker count: one per core.
pub fn default_service_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
}

/// Interned flight-recorder category for spans that belong to the service
/// loop itself rather than any one table (decode/queue/flush).
fn server_cat() -> u16 {
    static CAT: OnceLock<u16> = OnceLock::new();
    *CAT.get_or_init(|| trace::recorder().intern("_server"))
}

/// Server-side sampling promotion: an untraced request picks up a fresh
/// sampled context when the admin-tunable rotor says so, so span chains
/// exist even when no client stamps traces.
fn server_trace() -> Option<TraceContext> {
    trace::should_sample_server().then(TraceContext::generate)
}

/// Fold a finished request's stage durations into the per-table stage
/// histograms (the flight-recorder write happens inside
/// [`ReqSpans::finish`]).
fn finish_spans(shared: &EventShared, spans: ReqSpans, table: &str, started: Instant) {
    for (stage, d) in spans.finish(table, started) {
        if !d.is_zero() {
            shared.inner.record_stage(table, stage, d);
        }
    }
}

/// A table op the rate limiter (or gate) refused, suspended with its
/// connection. `noted` tracks the once-per-park blocked-episode metric.
enum ParkedOp {
    Insert {
        id: u64,
        table: Arc<Table>,
        item: Item,
        deadline: Instant,
        timeout: Duration,
        noted: bool,
        /// Dispatch time, for the service-time histogram (the recorded
        /// latency spans parked time, matching the blocking model).
        started: Instant,
        /// Stage accumulator (DESIGN.md §15); parked time folds into the
        /// `gate` stage on resume.
        spans: ReqSpans,
    },
    Sample {
        id: u64,
        table: Arc<Table>,
        n: usize,
        deadline: Instant,
        timeout: Duration,
        noted: bool,
        started: Instant,
        spans: ReqSpans,
    },
    /// A `CreateItemBatch` suspended at the op that blocked: `results`
    /// holds the outcomes already decided, `items` the blocked op and
    /// everything after it. The retry resumes exactly where it left off
    /// (the corridor-park contract, per op).
    InsertBatch {
        id: u64,
        /// Table of the op at the front — the waker registration target.
        table: Arc<Table>,
        items: VecDeque<WireItem>,
        results: Vec<BatchResult>,
        deadline: Instant,
        timeout: Duration,
        noted: bool,
        /// When the op currently at the front began (resets per op).
        started: Instant,
        /// When the whole batch was dispatched (the spans' origin).
        batch_started: Instant,
        spans: ReqSpans,
        /// The client-stamped context echoed on the `BatchReply`
        /// (server-promoted contexts stay server-internal so untraced
        /// peers get byte-identical replies).
        echo_trace: Option<TraceContext>,
        /// Table name the batch's span chain is attributed to (the first
        /// op's table; batches may span tables).
        span_table: String,
    },
}

impl ParkedOp {
    fn deadline(&self) -> Instant {
        match self {
            ParkedOp::Insert { deadline, .. }
            | ParkedOp::Sample { deadline, .. }
            | ParkedOp::InsertBatch { deadline, .. } => *deadline,
        }
    }
}

/// Why an op parked — decides which wakeup source to register.
#[derive(Clone, Copy, PartialEq, Eq)]
enum ParkKind {
    /// `Gate::try_enter` failed (checkpoint pause in progress).
    Gate,
    /// The insert corridor refused.
    Insert,
    /// The sample corridor refused (or an admitted insert is mid-flight).
    Sample,
}

/// Outcome of one attempt at a (possibly parked) op.
enum Attempt {
    /// Replied (success or error); the connection may resume reading.
    Done,
    /// Still blocked; park with this op and wakeup source.
    Parked(ParkedOp, ParkKind),
}

/// Outcome of dispatching one inbound frame.
enum Dispatch {
    Continue,
    Parked(ParkedOp, ParkKind),
}

/// Per-connection mutable state (the state machine's tape).
struct ConnState {
    stream: Box<dyn MsgStream>,
    source: PollSource,
    /// Chunks streamed on this connection, awaiting item creation.
    pending: HashMap<u64, crate::core::chunk_store::ChunkHandle>,
    pending_order: VecDeque<u64>,
    /// A dispatched op the limiter/gate refused; while `Some`, no further
    /// input is read (per-connection FIFO + backpressure).
    parked: Option<ParkedOp>,
    /// A reply flush hit `WouldBlock`; resume on writability.
    want_write: bool,
    /// Watch subscriptions on this connection: (watch id, table, alive
    /// flag). The table-side hooks hold only weak references plus the
    /// alive flag, so a closed connection's hooks unsubscribe themselves.
    watches: Vec<(u64, Arc<Table>, Arc<AtomicBool>)>,
    /// `Some` for `/metrics` scrape sockets, which ride the same poller
    /// and worker pool as data-plane connections but speak plain HTTP.
    http: Option<HttpScrape>,
    /// Trace of the most recent traced reply queued on this connection;
    /// taken by the next completed flush so the `flush` span lands on the
    /// request that produced the output.
    last_trace: Option<TraceContext>,
}

/// One served connection.
struct EventConn {
    id: u64,
    /// In the ready queue (or about to be serviced). Cleared by the worker
    /// before servicing so wakeups during service re-queue the connection
    /// rather than being lost.
    queued: AtomicBool,
    closed: AtomicBool,
    /// A watcher hook fired since the last service pass: emit one
    /// coalesced `WatchUpdate` per subscription (latest-wins).
    watch_dirty: AtomicBool,
    /// Recorder-epoch nanos when the connection entered the ready queue
    /// (0 = unstamped); the next service pass turns it into a `queue`
    /// stage measurement. One relaxed store per enqueue.
    enqueued_nanos: AtomicU64,
    state: Mutex<ConnState>,
}

/// State machine of one `/metrics` scrape riding the event loop: read the
/// request head non-blockingly, render once, then write the response
/// non-blockingly; close when done (replies are `Connection: close`, so
/// there is no keep-alive state).
struct HttpScrape {
    sock: std::net::TcpStream,
    head: Vec<u8>,
    /// Rendered response; `None` until the request head completes.
    response: Option<Vec<u8>>,
    written: usize,
}

/// Per-worker service counters, exported as
/// `reverb_worker_{dispatches,frames}_total`.
pub(crate) struct WorkerStats {
    /// Service passes this worker has run.
    pub(crate) dispatches: AtomicU64,
    /// Frames dispatched across those passes.
    pub(crate) frames: AtomicU64,
}

/// State shared by workers, the poller thread, accept threads, and the
/// wakers registered with tables/gate.
pub(crate) struct EventShared {
    inner: Arc<ServerInner>,
    poller: Poller,
    ready: Mutex<VecDeque<Arc<EventConn>>>,
    ready_cv: Condvar,
    conns: Mutex<HashMap<u64, Arc<EventConn>>>,
    /// Parked-op deadlines and retry slices, drained by the poller thread.
    timers: Mutex<BinaryHeap<Reverse<(Instant, u64)>>>,
    /// One entry per worker thread, indexed by spawn order.
    worker_stats: Vec<WorkerStats>,
    stop: AtomicBool,
    next_id: AtomicU64,
}

impl EventShared {
    /// Hand a freshly accepted connection to the pool.
    pub(crate) fn add_conn(self: &Arc<Self>, mut stream: Box<dyn MsgStream>) {
        if self.stop.load(Ordering::SeqCst) {
            return;
        }
        if stream.set_nonblocking(true).is_err() {
            return;
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let source = stream.poll_source();
        let conn = Arc::new(EventConn {
            id,
            queued: AtomicBool::new(false),
            closed: AtomicBool::new(false),
            watch_dirty: AtomicBool::new(false),
            enqueued_nanos: AtomicU64::new(0),
            state: Mutex::new(ConnState {
                stream,
                source,
                pending: HashMap::new(),
                pending_order: VecDeque::new(),
                parked: None,
                want_write: false,
                watches: Vec::new(),
                http: None,
                last_trace: None,
            }),
        });
        self.conns.lock().unwrap().insert(id, conn.clone());
        match source {
            PollSource::Fd(fd) => {
                // Interests are armed by the first service pass.
                self.poller.register(id, fd);
            }
            PollSource::Channel => {
                let waker = self.waker_for(&conn);
                conn.state.lock().unwrap().stream.set_ready_waker(waker);
            }
        }
        self.schedule(&conn);
    }

    /// Adopt an accepted `/metrics` scrape socket as another readiness
    /// source on the worker pool: scrapes ride the same poller and
    /// workers as the data plane instead of pinning a thread each. Gives
    /// the socket back (`Err`) where fd polling is unavailable (non-unix)
    /// so the caller can fall back to a thread per scrape.
    pub(crate) fn add_http_conn(
        self: &Arc<Self>,
        sock: std::net::TcpStream,
    ) -> std::result::Result<(), std::net::TcpStream> {
        #[cfg(not(unix))]
        {
            return Err(sock);
        }
        #[cfg(unix)]
        {
            // Dropping the socket on a stopping server (or a failed
            // nonblocking switch) is the correct outcome: the scrape just
            // sees a reset.
            if self.stop.load(Ordering::SeqCst) || sock.set_nonblocking(true).is_err() {
                return Ok(());
            }
            let fd = std::os::unix::io::AsRawFd::as_raw_fd(&sock);
            let id = self.next_id.fetch_add(1, Ordering::Relaxed);
            let conn = Arc::new(EventConn {
                id,
                queued: AtomicBool::new(false),
                closed: AtomicBool::new(false),
                watch_dirty: AtomicBool::new(false),
                enqueued_nanos: AtomicU64::new(0),
                state: Mutex::new(ConnState {
                    // HTTP bytes never touch the wire-protocol stream; the
                    // scrape socket lives in `http`.
                    stream: Box::new(ClosedStream),
                    source: PollSource::Fd(fd),
                    pending: HashMap::new(),
                    pending_order: VecDeque::new(),
                    parked: None,
                    want_write: false,
                    watches: Vec::new(),
                    http: Some(HttpScrape {
                        sock,
                        head: Vec::new(),
                        response: None,
                        written: 0,
                    }),
                    last_trace: None,
                }),
            });
            self.conns.lock().unwrap().insert(id, conn.clone());
            self.poller.register(id, fd);
            self.schedule(&conn);
            Ok(())
        }
    }

    /// Number of live connections (diagnostics / tests).
    pub(crate) fn live_conns(&self) -> usize {
        self.conns.lock().unwrap().len()
    }

    /// Per-worker service counters (metrics export).
    pub(crate) fn worker_stats(&self) -> &[WorkerStats] {
        &self.worker_stats
    }

    /// Queue a connection for a worker (idempotent; cheap enough to call
    /// from table wakers and client threads).
    fn schedule(&self, conn: &Arc<EventConn>) {
        if conn.closed.load(Ordering::SeqCst) {
            return;
        }
        if conn.queued.swap(true, Ordering::SeqCst) {
            return;
        }
        // Stamp the enqueue for the `queue` stage (max(1): zero means
        // "unstamped" to the reader).
        conn.enqueued_nanos.store(
            trace::recorder().nanos_since_epoch().max(1),
            Ordering::Relaxed,
        );
        self.ready.lock().unwrap().push_back(conn.clone());
        self.ready_cv.notify_one();
    }

    /// A one-shot wakeup closure for `conn`, weak on both ends so a
    /// stale hook outliving the connection (or the whole server) is inert.
    fn waker_for(self: &Arc<Self>, conn: &Arc<EventConn>) -> Arc<dyn Fn() + Send + Sync> {
        let shared = Arc::downgrade(self);
        let conn = Arc::downgrade(conn);
        Arc::new(move || {
            if let (Some(shared), Some(conn)) = (shared.upgrade(), conn.upgrade()) {
                shared.schedule(&conn);
            }
        })
    }

    fn add_timer(&self, at: Instant, conn_id: u64) {
        self.timers.lock().unwrap().push(Reverse((at, conn_id)));
        // The poller may be sleeping past the new deadline.
        self.poller.wake();
    }

    fn arm_read(&self, st: &ConnState, conn_id: u64) {
        if let PollSource::Fd(_) = st.source {
            self.poller.arm_read(conn_id);
        }
    }

    fn arm_write(&self, st: &ConnState, conn_id: u64) {
        if let PollSource::Fd(_) = st.source {
            self.poller.arm_write(conn_id);
        }
    }

    /// Tear a connection down: deregister, drop the socket *now* (fd
    /// hygiene — the queue may briefly hold the Arc), forget it.
    fn close(&self, conn: &EventConn, st: &mut ConnState) {
        if conn.closed.swap(true, Ordering::SeqCst) {
            return;
        }
        if let PollSource::Fd(_) = st.source {
            self.poller.deregister(conn.id);
        }
        st.stream = Box::new(ClosedStream);
        st.pending.clear();
        st.pending_order.clear();
        st.parked = None;
        // Flip alive flags before dropping the Arcs so watcher hooks that
        // are mid-fire see the cancellation; hooks holding only dead Weaks
        // unsubscribe themselves on their next firing either way.
        for (_, _, alive) in st.watches.drain(..) {
            alive.store(false, Ordering::SeqCst);
        }
        st.http = None;
        self.conns.lock().unwrap().remove(&conn.id);
    }
}

/// The worker pool + poller driving every connection of one server.
pub(crate) struct EventCore {
    shared: Arc<EventShared>,
    workers: Vec<std::thread::JoinHandle<()>>,
    poll_thread: Option<std::thread::JoinHandle<()>>,
}

impl EventCore {
    pub(crate) fn start(inner: Arc<ServerInner>, threads: usize) -> Result<EventCore> {
        let threads = threads.max(1);
        let shared = Arc::new(EventShared {
            inner,
            poller: Poller::new()?,
            ready: Mutex::new(VecDeque::new()),
            ready_cv: Condvar::new(),
            conns: Mutex::new(HashMap::new()),
            timers: Mutex::new(BinaryHeap::new()),
            worker_stats: (0..threads)
                .map(|_| WorkerStats {
                    dispatches: AtomicU64::new(0),
                    frames: AtomicU64::new(0),
                })
                .collect(),
            stop: AtomicBool::new(false),
            next_id: AtomicU64::new(1),
        });
        let mut workers = Vec::with_capacity(threads);
        for i in 0..threads {
            let s = shared.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("reverb-svc-{i}"))
                    .spawn(move || worker_loop(s, i))
                    .expect("spawn service worker"),
            );
        }
        let s = shared.clone();
        let poll_thread = std::thread::Builder::new()
            .name("reverb-poll".into())
            .spawn(move || poll_loop(s))
            .expect("spawn poll thread");
        Ok(EventCore {
            shared,
            workers,
            poll_thread: Some(poll_thread),
        })
    }

    pub(crate) fn shared(&self) -> Arc<EventShared> {
        self.shared.clone()
    }

    /// Stop the pool: workers drain the ready queue (so cancel-released
    /// parked ops still get their error replies), then exit; all
    /// connections are then closed.
    pub(crate) fn stop(&mut self) {
        if self.shared.stop.swap(true, Ordering::SeqCst) {
            return;
        }
        self.shared.poller.wake();
        {
            // Lock/unlock pairs with the workers' wait loop so the stop
            // flag is observed.
            drop(self.shared.ready.lock().unwrap());
        }
        self.shared.ready_cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        if let Some(p) = self.poll_thread.take() {
            let _ = p.join();
        }
        let conns: Vec<Arc<EventConn>> = {
            let mut map = self.shared.conns.lock().unwrap();
            map.drain().map(|(_, c)| c).collect()
        };
        for conn in conns {
            let mut st = conn.state.lock().unwrap();
            conn.closed.store(true, Ordering::SeqCst);
            st.stream = Box::new(ClosedStream);
        }
    }
}

impl Drop for EventCore {
    fn drop(&mut self) {
        self.stop();
    }
}

fn worker_loop(shared: Arc<EventShared>, idx: usize) {
    loop {
        let conn = {
            let mut q = shared.ready.lock().unwrap();
            loop {
                if let Some(c) = q.pop_front() {
                    break c;
                }
                if shared.stop.load(Ordering::SeqCst) {
                    return;
                }
                q = shared.ready_cv.wait(q).unwrap();
            }
        };
        conn.queued.store(false, Ordering::SeqCst);
        let frames = service(&shared, &conn);
        let stats = &shared.worker_stats[idx];
        stats.dispatches.fetch_add(1, Ordering::Relaxed);
        stats.frames.fetch_add(frames as u64, Ordering::Relaxed);
    }
}

fn poll_loop(shared: Arc<EventShared>) {
    while !shared.stop.load(Ordering::SeqCst) {
        // Fire due timers; find the next deadline.
        let now = Instant::now();
        let mut due = Vec::new();
        let mut next: Option<Instant> = None;
        {
            let mut timers = shared.timers.lock().unwrap();
            while let Some(&Reverse((at, id))) = timers.peek() {
                if at <= now {
                    timers.pop();
                    due.push(id);
                } else {
                    next = Some(at);
                    break;
                }
            }
        }
        for id in due {
            let conn = shared.conns.lock().unwrap().get(&id).cloned();
            if let Some(c) = conn {
                shared.schedule(&c);
            }
        }
        let timeout = match next {
            Some(at) => at.saturating_duration_since(now).min(POLL_TICK),
            None => POLL_TICK,
        };
        for token in shared.poller.poll(timeout) {
            let conn = shared.conns.lock().unwrap().get(&token).cloned();
            if let Some(c) = conn {
                shared.schedule(&c);
            }
        }
    }
}

/// One service pass over a connection's state machine. Returns the
/// number of frames dispatched (for the per-worker counters).
fn service(shared: &Arc<EventShared>, conn: &Arc<EventConn>) -> usize {
    let mut st = conn.state.lock().unwrap();
    if conn.closed.load(Ordering::SeqCst) {
        return 0;
    }
    if st.http.is_some() {
        service_http(shared, conn, &mut st);
        return 0;
    }
    let mut frames = 0usize;

    // Ready-queue wait: stamped by `schedule`, measured now (service
    // start), recorded later only if this pass does request work — idle
    // ticks must not drown the queue histogram.
    let queued_nanos = conn.enqueued_nanos.swap(0, Ordering::Relaxed);
    let queue_wait = (queued_nanos != 0).then(|| {
        Duration::from_nanos(
            trace::recorder()
                .nanos_since_epoch()
                .saturating_sub(queued_nanos),
        )
    });
    let service_started = Instant::now();
    let mut did_work = false;

    // 1. Retry a parked op (wakeup or timer brought us here).
    let mut may_read = true;
    if let Some(op) = st.parked.take() {
        did_work = true;
        match attempt_parked(shared, &mut st, op) {
            Ok(Attempt::Done) => {}
            Ok(Attempt::Parked(op, kind)) => {
                park(shared, conn, &mut st, op, kind);
                may_read = false;
            }
            Err(_) => {
                shared.close(conn, &mut st);
                return frames;
            }
        }
        if conn.closed.load(Ordering::SeqCst) {
            return frames;
        }
    }

    // 2. Resume a partial reply write before producing more output.
    if st.want_write {
        match st.stream.try_flush() {
            Ok(true) => st.want_write = false,
            Ok(false) => {
                shared.arm_write(&st, conn.id);
                return frames;
            }
            Err(_) => {
                shared.close(conn, &mut st);
                return frames;
            }
        }
    }

    // 3. Read + dispatch until the input drains (or we park / yield).
    if may_read && st.parked.is_none() {
        loop {
            if frames >= MAX_FRAMES_PER_SERVICE {
                // Fairness: let other connections at the workers; more
                // input may still be buffered, so come straight back.
                shared.schedule(conn);
                break;
            }
            let decode_started = Instant::now();
            match st.stream.try_recv() {
                Ok(Some(msg)) => {
                    frames += 1;
                    did_work = true;
                    // Decode stage: socket read + frame decode for this
                    // message. Attributed to the message's own context when
                    // it carries one; histograms always.
                    let decode = decode_started.elapsed();
                    shared.inner.record_stage("_server", Stage::Decode, decode);
                    let mtrace = match &msg {
                        Message::CreateItemBatch { trace, .. }
                        | Message::PriorityUpdateBatch { trace, .. } => *trace,
                        _ => None,
                    };
                    if mtrace.is_some() {
                        trace::recorder().record_at(
                            mtrace,
                            Stage::Decode,
                            server_cat(),
                            decode_started,
                            decode,
                        );
                    }
                    match dispatch(shared, conn, &mut st, msg) {
                        Ok(Dispatch::Continue) => continue,
                        Ok(Dispatch::Parked(op, kind)) => {
                            park(shared, conn, &mut st, op, kind);
                            break;
                        }
                        Err(_) => {
                            shared.close(conn, &mut st);
                            return frames;
                        }
                    }
                }
                Ok(None) => {
                    // Input drained: re-arm readiness (fd backends; the
                    // in-proc waker is persistent).
                    shared.arm_read(&st, conn.id);
                    break;
                }
                Err(_) => {
                    // Peer hung up (mid-frame drops land here too).
                    shared.close(conn, &mut st);
                    return frames;
                }
            }
        }
        if conn.closed.load(Ordering::SeqCst) {
            return frames;
        }
    }

    // 3.5. Push coalesced watch updates if any watcher hook fired since
    // the last pass: one current-state snapshot per subscription,
    // however many mutations landed meanwhile (latest-wins backpressure,
    // DESIGN.md §12).
    if conn.watch_dirty.swap(false, Ordering::SeqCst) && !st.watches.is_empty() {
        let stt = &mut *st;
        let mut failed = false;
        for (id, table, _alive) in &stt.watches {
            let update = Message::WatchUpdate {
                id: *id,
                table: table.name().to_string(),
                info: table.info(),
            };
            if stt.stream.send(update).is_err() {
                failed = true;
                break;
            }
        }
        if failed {
            shared.close(conn, &mut st);
            return frames;
        }
    }

    // The queue stage covers enqueue → service start; laid down once the
    // pass is known to have done request work.
    if let (true, Some(dur)) = (did_work, queue_wait) {
        shared.inner.record_stage("_server", Stage::Queue, dur);
        let start = service_started.checked_sub(dur).unwrap_or(service_started);
        trace::recorder().record_at(None, Stage::Queue, server_cat(), start, dur);
    }

    // 4. Flush replies produced this pass.
    let flush_started = Instant::now();
    match st.stream.try_flush() {
        Ok(true) => {
            if did_work {
                let dur = flush_started.elapsed();
                shared.inner.record_stage("_server", Stage::Flush, dur);
                if let Some(ftrace) = st.last_trace.take() {
                    trace::recorder().record_at(
                        Some(ftrace),
                        Stage::Flush,
                        server_cat(),
                        flush_started,
                        dur,
                    );
                }
            }
        }
        Ok(false) => {
            st.want_write = true;
            shared.arm_write(&st, conn.id);
        }
        Err(_) => shared.close(conn, &mut st),
    }
    frames
}

/// One service pass over a `/metrics` scrape socket: read the request
/// head, render the response once, write it out, close. Re-arms poller
/// interest on `WouldBlock` at either end.
fn service_http(shared: &Arc<EventShared>, conn: &Arc<EventConn>, st: &mut ConnState) {
    use std::io::{ErrorKind, Read, Write};
    // Take the scrape state out so socket I/O does not hold a field
    // borrow across `close`/`arm_*` calls, which take the whole state.
    let Some(mut http) = st.http.take() else {
        return;
    };
    if http.response.is_none() {
        let mut buf = [0u8; 1024];
        loop {
            if crate::net::metrics::head_complete(&http.head) {
                break;
            }
            if http.head.len() > crate::net::metrics::MAX_HTTP_HEAD {
                shared.close(conn, st);
                return;
            }
            match http.sock.read(&mut buf) {
                Ok(0) => {
                    shared.close(conn, st);
                    return;
                }
                Ok(n) => http.head.extend_from_slice(&buf[..n]),
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    st.http = Some(http);
                    shared.arm_read(st, conn.id);
                    return;
                }
                Err(_) => {
                    shared.close(conn, st);
                    return;
                }
            }
        }
        http.response = Some(crate::net::metrics::http_response(
            &http.head,
            &shared.inner,
            Some(shared),
        ));
    }
    loop {
        let resp = http.response.as_ref().expect("response rendered above");
        if http.written >= resp.len() {
            break;
        }
        match http.sock.write(&resp[http.written..]) {
            // A zero-length write means the peer stopped reading: done.
            Ok(0) => break,
            Ok(n) => http.written += n,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                st.http = Some(http);
                shared.arm_write(st, conn.id);
                return;
            }
            Err(_) => break,
        }
    }
    // Fully written (or unrecoverable): responses are `Connection:
    // close`, so tear down.
    shared.close(conn, st);
}

/// Park `op` on its wakeup source, then re-attempt once: a notification
/// that fired between the failed attempt and hook registration would
/// otherwise be lost (see `Waiters::add_hook`). The retry timer bounds
/// staleness in every remaining race.
fn park(
    shared: &Arc<EventShared>,
    conn: &Arc<EventConn>,
    st: &mut ConnState,
    op: ParkedOp,
    kind: ParkKind,
) {
    let waker = shared.waker_for(conn);
    let slice = match kind {
        ParkKind::Gate => GATE_SLICE,
        ParkKind::Insert | ParkKind::Sample => PARK_SLICE,
    };
    let retry_at = (Instant::now() + slice).min(op.deadline());
    match (&op, kind) {
        (_, ParkKind::Gate) => shared.inner.gate.register_resume_waker(waker),
        (ParkedOp::Insert { table, .. } | ParkedOp::InsertBatch { table, .. }, _) => {
            table.register_insert_waker(waker)
        }
        (ParkedOp::Sample { table, .. }, _) => table.register_sample_waker(waker),
    }
    shared.add_timer(retry_at, conn.id);
    match attempt_parked(shared, st, op) {
        Ok(Attempt::Done) => {}
        Ok(Attempt::Parked(op, _)) => st.parked = Some(op),
        Err(_) => shared.close(conn, st),
    }
}

/// Retry a parked op.
fn attempt_parked(shared: &Arc<EventShared>, st: &mut ConnState, op: ParkedOp) -> Result<Attempt> {
    match op {
        ParkedOp::Insert {
            id,
            table,
            item,
            deadline,
            timeout,
            noted,
            started,
            spans,
        } => attempt_insert(
            shared, st, id, table, item, deadline, timeout, noted, started, spans,
        ),
        ParkedOp::Sample {
            id,
            table,
            n,
            deadline,
            timeout,
            noted,
            started,
            spans,
        } => attempt_sample(
            shared, st, id, table, n, deadline, timeout, noted, started, spans,
        ),
        ParkedOp::InsertBatch {
            id,
            table: _,
            items,
            results,
            deadline,
            timeout,
            noted,
            started,
            batch_started,
            spans,
            echo_trace,
            span_table,
        } => attempt_insert_batch(
            shared,
            st,
            id,
            items,
            results,
            deadline,
            timeout,
            noted,
            started,
            batch_started,
            spans,
            echo_trace,
            span_table,
        ),
    }
}

/// One non-blocking insert attempt. The gate guard is held only for the
/// duration of the try — a corridor park never pins a worker *or* holds
/// the gate open.
#[allow(clippy::too_many_arguments)]
fn attempt_insert(
    shared: &Arc<EventShared>,
    st: &mut ConnState,
    id: u64,
    table: Arc<Table>,
    item: Item,
    deadline: Instant,
    timeout: Duration,
    noted: bool,
    started: Instant,
    mut spans: ReqSpans,
) -> Result<Attempt> {
    // A retry after a park lands here: fold the parked window into the
    // gate stage (no-op on the first attempt).
    spans.resumed();
    let Some(_guard) = shared.inner.gate.try_enter() else {
        spans.parked();
        return Ok(Attempt::Parked(
            ParkedOp::Insert {
                id,
                table,
                item,
                deadline,
                timeout,
                noted,
                started,
                spans,
            },
            ParkKind::Gate,
        ));
    };
    let op_started = Instant::now();
    let outcome = table.try_insert_or_assign(item);
    spans.op_attempt(op_started.elapsed());
    match outcome {
        Ok(TryInsertOutcome::Inserted) => {
            shared.inner.record_insert_latency(table.name(), started);
            if spans.trace.is_some() {
                st.last_trace = spans.trace;
            }
            finish_spans(shared, spans, table.name(), started);
            send_reply(st, id, Ok(String::new()))?;
            Ok(Attempt::Done)
        }
        Ok(TryInsertOutcome::Blocked(item)) => {
            if Instant::now() >= deadline {
                shared.inner.record_insert_latency(table.name(), started);
                finish_spans(shared, spans, table.name(), started);
                send_reply(st, id, Err(Error::RateLimiterTimeout(timeout)))?;
                return Ok(Attempt::Done);
            }
            if !noted {
                table.note_blocked_insert();
            }
            spans.parked();
            Ok(Attempt::Parked(
                ParkedOp::Insert {
                    id,
                    table,
                    item,
                    deadline,
                    timeout,
                    noted: true,
                    started,
                    spans,
                },
                ParkKind::Insert,
            ))
        }
        Err(e) => {
            shared.inner.record_insert_latency(table.name(), started);
            finish_spans(shared, spans, table.name(), started);
            send_reply(st, id, Err(e))?;
            Ok(Attempt::Done)
        }
    }
}

/// One pass over a (possibly resumed) `CreateItemBatch`: apply ops from
/// the front until the batch drains or one blocks. Per-op failures
/// (unknown table, unresolvable item, deadline) fill their result slot
/// and never abort the ops after them; only a corridor/gate refusal
/// before the deadline parks — holding the connection at exactly the op
/// that blocked, with everything already decided kept in `results`.
/// Items are re-resolved from their wire form on retry: `resolve_item`
/// is non-destructive and the pending set cannot shrink while parked
/// (a parked connection reads no input).
#[allow(clippy::too_many_arguments)]
fn attempt_insert_batch(
    shared: &Arc<EventShared>,
    st: &mut ConnState,
    id: u64,
    mut items: VecDeque<WireItem>,
    mut results: Vec<BatchResult>,
    deadline: Instant,
    timeout: Duration,
    mut noted: bool,
    mut op_started: Instant,
    batch_started: Instant,
    mut spans: ReqSpans,
    echo_trace: Option<TraceContext>,
    span_table: String,
) -> Result<Attempt> {
    spans.resumed();
    loop {
        let Some(wire_item) = items.front() else {
            st.stream.send(Message::BatchReply {
                id,
                results,
                trace: echo_trace,
            })?;
            if spans.trace.is_some() {
                st.last_trace = spans.trace;
            }
            finish_spans(shared, spans, &span_table, batch_started);
            return Ok(Attempt::Done);
        };
        let table = match shared.inner.table(&wire_item.table) {
            Ok(t) => t.clone(),
            Err(e) => {
                results.push(BatchResult::from_result(Err(&e)));
                items.pop_front();
                op_started = Instant::now();
                continue;
            }
        };
        let item = match resolve_item(&shared.inner, &st.pending, wire_item) {
            Ok(i) => i,
            Err(e) => {
                results.push(BatchResult::from_result(Err(&e)));
                items.pop_front();
                op_started = Instant::now();
                continue;
            }
        };
        let Some(_guard) = shared.inner.gate.try_enter() else {
            spans.parked();
            return Ok(Attempt::Parked(
                ParkedOp::InsertBatch {
                    id,
                    table,
                    items,
                    results,
                    deadline,
                    timeout,
                    noted,
                    started: op_started,
                    batch_started,
                    spans,
                    echo_trace,
                    span_table,
                },
                ParkKind::Gate,
            ));
        };
        let try_started = Instant::now();
        let outcome = table.try_insert_or_assign(item);
        spans.op_attempt(try_started.elapsed());
        match outcome {
            Ok(TryInsertOutcome::Inserted) => {
                shared.inner.record_insert_latency(&wire_item.table, op_started);
                results.push(BatchResult::Ok { detail: String::new() });
                items.pop_front();
                noted = false;
                op_started = Instant::now();
            }
            Ok(TryInsertOutcome::Blocked(_)) => {
                if Instant::now() >= deadline {
                    shared.inner.record_insert_latency(&wire_item.table, op_started);
                    let e = Error::RateLimiterTimeout(timeout);
                    results.push(BatchResult::from_result(Err(&e)));
                    items.pop_front();
                    noted = false;
                    op_started = Instant::now();
                    continue;
                }
                if !noted {
                    table.note_blocked_insert();
                }
                spans.parked();
                return Ok(Attempt::Parked(
                    ParkedOp::InsertBatch {
                        id,
                        table,
                        items,
                        results,
                        deadline,
                        timeout,
                        noted: true,
                        started: op_started,
                        batch_started,
                        spans,
                        echo_trace,
                        span_table,
                    },
                    ParkKind::Insert,
                ));
            }
            Err(e) => {
                shared.inner.record_insert_latency(&wire_item.table, op_started);
                results.push(BatchResult::from_result(Err(&e)));
                items.pop_front();
                noted = false;
                op_started = Instant::now();
            }
        }
    }
}

/// One non-blocking sample attempt (see [`attempt_insert`]).
#[allow(clippy::too_many_arguments)]
fn attempt_sample(
    shared: &Arc<EventShared>,
    st: &mut ConnState,
    id: u64,
    table: Arc<Table>,
    n: usize,
    deadline: Instant,
    timeout: Duration,
    noted: bool,
    started: Instant,
    mut spans: ReqSpans,
) -> Result<Attempt> {
    spans.resumed();
    let Some(_guard) = shared.inner.gate.try_enter() else {
        spans.parked();
        return Ok(Attempt::Parked(
            ParkedOp::Sample {
                id,
                table,
                n,
                deadline,
                timeout,
                noted,
                started,
                spans,
            },
            ParkKind::Gate,
        ));
    };
    let op_started = Instant::now();
    let outcome = table.try_sample_batch(n);
    spans.op_attempt(op_started.elapsed());
    match outcome {
        Ok(TrySampleOutcome::Sampled(samples)) => {
            shared.inner.record_sample_latency(table.name(), started);
            // A cold-tier rehydration failure is an op-level error reply,
            // not a connection-fatal one.
            match sample_reply(id, &samples) {
                Ok(reply) => st.stream.send(reply)?,
                Err(e) => send_err(st, id, &e)?,
            }
            if spans.trace.is_some() {
                st.last_trace = spans.trace;
            }
            let name = table.name().to_string();
            finish_spans(shared, spans, &name, started);
            Ok(Attempt::Done)
        }
        Ok(TrySampleOutcome::Blocked) => {
            if Instant::now() >= deadline {
                shared.inner.record_sample_latency(table.name(), started);
                send_err(st, id, &Error::RateLimiterTimeout(timeout))?;
                let name = table.name().to_string();
                finish_spans(shared, spans, &name, started);
                return Ok(Attempt::Done);
            }
            if !noted {
                table.note_blocked_sample();
            }
            spans.parked();
            Ok(Attempt::Parked(
                ParkedOp::Sample {
                    id,
                    table,
                    n,
                    deadline,
                    timeout,
                    noted: true,
                    started,
                    spans,
                },
                ParkKind::Sample,
            ))
        }
        Err(e) => {
            shared.inner.record_sample_latency(table.name(), started);
            send_err(st, id, &e)?;
            let name = table.name().to_string();
            finish_spans(shared, spans, &name, started);
            Ok(Attempt::Done)
        }
    }
}

/// Dispatch one inbound frame. `Err` is connection-fatal (reply channel
/// broken or protocol violation); op-level failures become error replies.
fn dispatch(
    shared: &Arc<EventShared>,
    conn: &Arc<EventConn>,
    st: &mut ConnState,
    msg: Message,
) -> Result<Dispatch> {
    match msg {
        Message::InsertChunks { chunks } => {
            stash_chunks(
                &shared.inner,
                &mut st.pending,
                &mut st.pending_order,
                chunks,
            );
            // No reply: chunk streaming is fire-and-forget, acks ride on
            // the subsequent CreateItem.
            Ok(Dispatch::Continue)
        }
        Message::CreateItem { id, item, timeout_ms } => {
            let started = Instant::now();
            let table = match shared.inner.table(&item.table) {
                Ok(t) => t.clone(),
                Err(e) => {
                    send_reply(st, id, Err(e))?;
                    return Ok(Dispatch::Continue);
                }
            };
            let resolved = match resolve_item(&shared.inner, &st.pending, &item) {
                Ok(i) => i,
                Err(e) => {
                    send_reply(st, id, Err(e))?;
                    return Ok(Dispatch::Continue);
                }
            };
            let timeout = Duration::from_millis(timeout_ms).min(MAX_OP_TIMEOUT);
            let deadline = Instant::now() + timeout;
            let spans = ReqSpans::new(server_trace());
            match attempt_insert(
                shared, st, id, table, resolved, deadline, timeout, false, started, spans,
            )? {
                Attempt::Done => Ok(Dispatch::Continue),
                Attempt::Parked(op, kind) => Ok(Dispatch::Parked(op, kind)),
            }
        }
        Message::CreateItemBatch { id, items, timeout_ms, trace } => {
            if items.len() > MAX_BATCH_OPS {
                send_err(st, id, &batch_too_large(items.len()))?;
                return Ok(Dispatch::Continue);
            }
            let timeout = Duration::from_millis(timeout_ms).min(MAX_OP_TIMEOUT);
            let deadline = Instant::now() + timeout;
            let cap = items.len();
            let batch_started = Instant::now();
            // Span chains attribute the whole batch to the first op's
            // table; a client-stamped context wins over server promotion
            // and is the only one echoed back on the reply (DESIGN.md §15).
            let span_table = items
                .first()
                .map(|i| i.table.clone())
                .unwrap_or_else(|| "_server".to_string());
            let spans = ReqSpans::new(trace.or_else(server_trace));
            match attempt_insert_batch(
                shared,
                st,
                id,
                VecDeque::from(items),
                Vec::with_capacity(cap),
                deadline,
                timeout,
                false,
                batch_started,
                batch_started,
                spans,
                trace,
                span_table,
            )? {
                Attempt::Done => Ok(Dispatch::Continue),
                Attempt::Parked(op, kind) => Ok(Dispatch::Parked(op, kind)),
            }
        }
        Message::SampleRequest {
            id,
            table,
            num_samples,
            timeout_ms,
        } => {
            let started = Instant::now();
            let table = match shared.inner.table(&table) {
                Ok(t) => t.clone(),
                Err(e) => {
                    send_err(st, id, &e)?;
                    return Ok(Dispatch::Continue);
                }
            };
            let n = num_samples.max(1) as usize;
            let timeout = Duration::from_millis(timeout_ms).min(MAX_OP_TIMEOUT);
            let deadline = Instant::now() + timeout;
            let spans = ReqSpans::new(server_trace());
            match attempt_sample(
                shared, st, id, table, n, deadline, timeout, false, started, spans,
            )? {
                Attempt::Done => Ok(Dispatch::Continue),
                Attempt::Parked(op, kind) => Ok(Dispatch::Parked(op, kind)),
            }
        }
        Message::MutatePriorities {
            id,
            table,
            updates,
            deletes,
        } => {
            let reply = (|| {
                let table = shared.inner.table(&table)?.clone();
                // Mutations never park on the rate limiter; a blocking
                // gate entry is bounded by the (short) checkpoint pause.
                let _guard = shared.inner.gate.enter();
                let updated = table.update_priorities(&updates)?;
                let deleted = table.delete(&deletes)?;
                Ok(format!("updated={updated} deleted={deleted}"))
            })();
            send_reply(st, id, reply)?;
            Ok(Dispatch::Continue)
        }
        Message::PriorityUpdateBatch { id, ops, trace } => {
            if ops.len() > MAX_BATCH_OPS {
                send_err(st, id, &batch_too_large(ops.len()))?;
                return Ok(Dispatch::Continue);
            }
            let started = Instant::now();
            let mut spans = ReqSpans::new(trace.or_else(server_trace));
            // Mutations never park: one gate entry covers the whole batch,
            // and each op's keys are already grouped per shard by
            // `update_priorities`/`delete` — N ops cost one gate
            // acquisition and one lock hold per touched shard.
            let results = {
                let (_guard, waited) = shared.inner.gate.enter_timed();
                spans.gate += waited;
                let op_started = Instant::now();
                let results: Vec<BatchResult> = ops
                    .iter()
                    .map(|op| {
                        let r = (|| {
                            let table = shared.inner.table(&op.table)?;
                            let updated = table.update_priorities(&op.updates)?;
                            let deleted = table.delete(&op.deletes)?;
                            Ok(format!("updated={updated} deleted={deleted}"))
                        })();
                        BatchResult::from_result(r.as_ref().map(String::clone))
                    })
                    .collect();
                spans.op_attempt(op_started.elapsed());
                results
            };
            // Update batches span tables; attribute the chain to the first
            // op's table like CreateItemBatch does.
            let span_table = ops
                .first()
                .map(|op| op.table.clone())
                .unwrap_or_else(|| "_server".to_string());
            st.stream.send(Message::BatchReply { id, results, trace })?;
            if spans.trace.is_some() {
                st.last_trace = spans.trace;
            }
            finish_spans(shared, spans, &span_table, started);
            Ok(Dispatch::Continue)
        }
        Message::Reset { id, table } => {
            let reply = (|| {
                let table = shared.inner.table(&table)?.clone();
                let _guard = shared.inner.gate.enter();
                table.reset();
                Ok(String::new())
            })();
            send_reply(st, id, reply)?;
            Ok(Dispatch::Continue)
        }
        Message::InfoRequest { id } => {
            let tables = shared
                .inner
                .table_order
                .iter()
                .map(|t| (t.name().to_string(), t.info()))
                .collect();
            st.stream.send(Message::Info { id, tables })?;
            Ok(Dispatch::Continue)
        }
        Message::Ping { id, nonce } => {
            // Pure service-loop echo: no table access, no gate — probe
            // latency measures dispatch health only (DESIGN.md §14).
            st.stream.send(Message::Pong { id, nonce })?;
            Ok(Dispatch::Continue)
        }
        Message::Checkpoint { id } => {
            // Deliberately synchronous on the worker: checkpoints are rare
            // and gate-serialized; parked connections re-arm off the gate's
            // resume hook, so the pause never wedges the pool.
            let reply = shared.inner.checkpoint().map(|p| p.display().to_string());
            send_reply(st, id, reply)?;
            Ok(Dispatch::Continue)
        }
        Message::AdminReconfig {
            id,
            table,
            max_size,
            min_diff,
            max_diff,
            checkpoint_interval_ms,
            slow_request_micros,
            trace_sample_per_mille,
        } => {
            let reply = shared.inner.apply_admin(
                &table,
                max_size,
                min_diff,
                max_diff,
                checkpoint_interval_ms,
                slow_request_micros,
                trace_sample_per_mille,
            );
            send_reply(st, id, reply)?;
            Ok(Dispatch::Continue)
        }
        Message::WatchRequest { id, table } => {
            match shared.inner.table(&table) {
                Ok(t) => {
                    let t = t.clone();
                    let alive = Arc::new(AtomicBool::new(true));
                    let hook_shared = Arc::downgrade(shared);
                    let hook_conn = Arc::downgrade(conn);
                    let hook_alive = Arc::downgrade(&alive);
                    // The hook only flips a dirty bit and schedules the
                    // connection — it runs on mutating threads outside
                    // shard locks and must never call back into the table.
                    t.register_watcher(Box::new(move || {
                        let (Some(shared), Some(conn), Some(alive)) = (
                            hook_shared.upgrade(),
                            hook_conn.upgrade(),
                            hook_alive.upgrade(),
                        ) else {
                            return false;
                        };
                        if conn.closed.load(Ordering::SeqCst) || !alive.load(Ordering::SeqCst) {
                            return false;
                        }
                        conn.watch_dirty.store(true, Ordering::SeqCst);
                        shared.schedule(&conn);
                        true
                    }));
                    st.watches.push((id, t.clone(), alive));
                    // Immediate snapshot: the baseline the deltas follow.
                    st.stream.send(Message::WatchUpdate {
                        id,
                        table,
                        info: t.info(),
                    })?;
                }
                Err(e) => send_err(st, id, &e)?,
            }
            Ok(Dispatch::Continue)
        }
        Message::WatchCancel { id } => {
            let before = st.watches.len();
            st.watches.retain(|(wid, _, alive)| {
                if *wid == id {
                    alive.store(false, Ordering::SeqCst);
                    false
                } else {
                    true
                }
            });
            // Idempotent by design: cancelling an unknown id acks 0.
            let n = before - st.watches.len();
            send_reply(st, id, Ok(format!("cancelled={n}")))?;
            Ok(Dispatch::Continue)
        }
        // Server-to-client messages arriving at the server are protocol
        // violations.
        Message::Ack { .. }
        | Message::Err { .. }
        | Message::SampleData { .. }
        | Message::Info { .. }
        | Message::WatchUpdate { .. }
        | Message::BatchReply { .. }
        | Message::Pong { .. } => {
            Err(Error::Decode("client sent a server-side message".into()))
        }
    }
}

/// Queue an Ack/Err reply (no flush — the service pass flushes once per
/// batch).
fn send_reply(st: &mut ConnState, id: u64, result: Result<String>) -> Result<()> {
    let msg = match result {
        Ok(detail) => Message::Ack { id, detail },
        Err(e) => Message::Err {
            id,
            code: error_code(&e),
            message: e.to_string(),
        },
    };
    st.stream.send(msg)
}

fn send_err(st: &mut ConnState, id: u64, e: &Error) -> Result<()> {
    st.stream.send(Message::Err {
        id,
        code: error_code(e),
        message: e.to_string(),
    })
}

/// Stand-in installed when a connection closes, so the real socket drops
/// (and its fd is returned to the OS) immediately even if the ready queue
/// still holds the connection handle for a moment.
struct ClosedStream;

impl MsgStream for ClosedStream {
    fn send(&mut self, _msg: Message) -> Result<()> {
        Err(closed())
    }
    fn flush(&mut self) -> Result<()> {
        Err(closed())
    }
    fn recv(&mut self) -> Result<Message> {
        Err(closed())
    }
    fn transport(&self) -> &'static str {
        "closed"
    }
    fn set_nonblocking(&mut self, _nonblocking: bool) -> Result<()> {
        Ok(())
    }
    fn poll_source(&self) -> PollSource {
        PollSource::Channel
    }
    fn try_recv(&mut self) -> Result<Option<Message>> {
        Err(closed())
    }
    fn try_flush(&mut self) -> Result<bool> {
        Err(closed())
    }
}

fn closed() -> Error {
    Error::Io(std::io::Error::new(
        std::io::ErrorKind::NotConnected,
        "connection closed",
    ))
}
