//! The event-driven service core (DESIGN.md §11): `N = service_threads`
//! workers drive per-connection state machines over readiness signals,
//! decoupling connection count from OS-thread count — the prerequisite for
//! the paper's "thousands of concurrent clients" serving claim.
//!
//! # Architecture
//!
//! - **Poller thread** — sleeps in `ppoll(2)` ([`crate::net::poller`])
//!   over every fd-backed connection with an armed interest, plus a timer
//!   heap for parked-operation deadlines and retry slices. Readiness or a
//!   due timer *schedules* the connection onto the ready queue.
//! - **Worker pool** — `N` threads pop scheduled connections and run each
//!   connection's state machine: retry a parked op, resume a partial
//!   write, read frames (`try_recv`, resumable mid-frame), dispatch, and
//!   flush (`try_flush`, resumable mid-write).
//! - **Parked operations** — a `CreateItem`/`SampleRequest` whose rate
//!   limiter (or the checkpoint gate) refuses does NOT pin a worker: the
//!   connection parks with the op, registers a one-shot waker on the
//!   table's waiter lists ([`Table::register_insert_waker`] /
//!   [`Table::register_sample_waker`]) or the gate's resume hook, arms a
//!   bounded retry timer, and the worker moves on. The table's existing
//!   condvar wakeup paths fire the hooks, so corridor wakeups re-arm
//!   connections with the same precision the blocking path enjoys.
//!
//! Per-connection FIFO semantics are preserved by construction: while an
//! op is parked the connection reads no further input (the kernel socket
//! buffer / bounded in-proc channel provides the same client-side
//! backpressure the blocked service thread used to), and replies are
//! written in dispatch order.
//!
//! In-proc connections have no fd; their readiness rides the channel
//! occupancy wakers ([`MsgStream::set_ready_waker`]) instead of the
//! poller.

use crate::core::item::Item;
use crate::core::table::{Table, TryInsertOutcome, TrySampleOutcome};
use crate::error::{Error, Result};
use crate::net::poller::Poller;
use crate::net::server::{resolve_item, sample_reply, stash_chunks, ServerInner};
use crate::net::transport::{MsgStream, PollSource};
use crate::net::wire::{error_code, Message};
use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Upper bound on frames handled in one service pass, so one firehose
/// connection cannot starve the others (it re-schedules itself instead).
const MAX_FRAMES_PER_SERVICE: usize = 128;

/// Retry slice for limiter-parked ops: the waker is the fast path; the
/// timer bounds staleness exactly like the blocking path's `WAIT_SLICE`.
const PARK_SLICE: Duration = Duration::from_millis(50);

/// Retry slice for gate-parked ops (checkpoint pauses are short).
const GATE_SLICE: Duration = Duration::from_millis(2);

/// Poller tick when no timer is due sooner.
const POLL_TICK: Duration = Duration::from_millis(25);

/// Cap on client-supplied op timeouts: practically infinite, while
/// keeping `Instant + timeout` arithmetic overflow-free for adversarial
/// `timeout_ms` values (a worker must never panic on wire input).
const MAX_OP_TIMEOUT: Duration = Duration::from_secs(30 * 24 * 3600);

/// Default worker count: one per core.
pub fn default_service_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
}

/// A table op the rate limiter (or gate) refused, suspended with its
/// connection. `noted` tracks the once-per-park blocked-episode metric.
enum ParkedOp {
    Insert {
        id: u64,
        table: Arc<Table>,
        item: Item,
        deadline: Instant,
        timeout: Duration,
        noted: bool,
    },
    Sample {
        id: u64,
        table: Arc<Table>,
        n: usize,
        deadline: Instant,
        timeout: Duration,
        noted: bool,
    },
}

impl ParkedOp {
    fn deadline(&self) -> Instant {
        match self {
            ParkedOp::Insert { deadline, .. } | ParkedOp::Sample { deadline, .. } => *deadline,
        }
    }
}

/// Why an op parked — decides which wakeup source to register.
#[derive(Clone, Copy, PartialEq, Eq)]
enum ParkKind {
    /// `Gate::try_enter` failed (checkpoint pause in progress).
    Gate,
    /// The insert corridor refused.
    Insert,
    /// The sample corridor refused (or an admitted insert is mid-flight).
    Sample,
}

/// Outcome of one attempt at a (possibly parked) op.
enum Attempt {
    /// Replied (success or error); the connection may resume reading.
    Done,
    /// Still blocked; park with this op and wakeup source.
    Parked(ParkedOp, ParkKind),
}

/// Outcome of dispatching one inbound frame.
enum Dispatch {
    Continue,
    Parked(ParkedOp, ParkKind),
}

/// Per-connection mutable state (the state machine's tape).
struct ConnState {
    stream: Box<dyn MsgStream>,
    source: PollSource,
    /// Chunks streamed on this connection, awaiting item creation.
    pending: HashMap<u64, Arc<crate::core::chunk::Chunk>>,
    pending_order: VecDeque<u64>,
    /// A dispatched op the limiter/gate refused; while `Some`, no further
    /// input is read (per-connection FIFO + backpressure).
    parked: Option<ParkedOp>,
    /// A reply flush hit `WouldBlock`; resume on writability.
    want_write: bool,
}

/// One served connection.
struct EventConn {
    id: u64,
    /// In the ready queue (or about to be serviced). Cleared by the worker
    /// before servicing so wakeups during service re-queue the connection
    /// rather than being lost.
    queued: AtomicBool,
    closed: AtomicBool,
    state: Mutex<ConnState>,
}

/// State shared by workers, the poller thread, accept threads, and the
/// wakers registered with tables/gate.
pub(crate) struct EventShared {
    inner: Arc<ServerInner>,
    poller: Poller,
    ready: Mutex<VecDeque<Arc<EventConn>>>,
    ready_cv: Condvar,
    conns: Mutex<HashMap<u64, Arc<EventConn>>>,
    /// Parked-op deadlines and retry slices, drained by the poller thread.
    timers: Mutex<BinaryHeap<Reverse<(Instant, u64)>>>,
    stop: AtomicBool,
    next_id: AtomicU64,
}

impl EventShared {
    /// Hand a freshly accepted connection to the pool.
    pub(crate) fn add_conn(self: &Arc<Self>, mut stream: Box<dyn MsgStream>) {
        if self.stop.load(Ordering::SeqCst) {
            return;
        }
        if stream.set_nonblocking(true).is_err() {
            return;
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let source = stream.poll_source();
        let conn = Arc::new(EventConn {
            id,
            queued: AtomicBool::new(false),
            closed: AtomicBool::new(false),
            state: Mutex::new(ConnState {
                stream,
                source,
                pending: HashMap::new(),
                pending_order: VecDeque::new(),
                parked: None,
                want_write: false,
            }),
        });
        self.conns.lock().unwrap().insert(id, conn.clone());
        match source {
            PollSource::Fd(fd) => {
                // Interests are armed by the first service pass.
                self.poller.register(id, fd);
            }
            PollSource::Channel => {
                let waker = self.waker_for(&conn);
                conn.state.lock().unwrap().stream.set_ready_waker(waker);
            }
        }
        self.schedule(&conn);
    }

    /// Number of live connections (diagnostics / tests).
    pub(crate) fn live_conns(&self) -> usize {
        self.conns.lock().unwrap().len()
    }

    /// Queue a connection for a worker (idempotent; cheap enough to call
    /// from table wakers and client threads).
    fn schedule(&self, conn: &Arc<EventConn>) {
        if conn.closed.load(Ordering::SeqCst) {
            return;
        }
        if conn.queued.swap(true, Ordering::SeqCst) {
            return;
        }
        self.ready.lock().unwrap().push_back(conn.clone());
        self.ready_cv.notify_one();
    }

    /// A one-shot wakeup closure for `conn`, weak on both ends so a
    /// stale hook outliving the connection (or the whole server) is inert.
    fn waker_for(self: &Arc<Self>, conn: &Arc<EventConn>) -> Arc<dyn Fn() + Send + Sync> {
        let shared = Arc::downgrade(self);
        let conn = Arc::downgrade(conn);
        Arc::new(move || {
            if let (Some(shared), Some(conn)) = (shared.upgrade(), conn.upgrade()) {
                shared.schedule(&conn);
            }
        })
    }

    fn add_timer(&self, at: Instant, conn_id: u64) {
        self.timers.lock().unwrap().push(Reverse((at, conn_id)));
        // The poller may be sleeping past the new deadline.
        self.poller.wake();
    }

    fn arm_read(&self, st: &ConnState, conn_id: u64) {
        if let PollSource::Fd(_) = st.source {
            self.poller.arm_read(conn_id);
        }
    }

    fn arm_write(&self, st: &ConnState, conn_id: u64) {
        if let PollSource::Fd(_) = st.source {
            self.poller.arm_write(conn_id);
        }
    }

    /// Tear a connection down: deregister, drop the socket *now* (fd
    /// hygiene — the queue may briefly hold the Arc), forget it.
    fn close(&self, conn: &EventConn, st: &mut ConnState) {
        if conn.closed.swap(true, Ordering::SeqCst) {
            return;
        }
        if let PollSource::Fd(_) = st.source {
            self.poller.deregister(conn.id);
        }
        st.stream = Box::new(ClosedStream);
        st.pending.clear();
        st.pending_order.clear();
        st.parked = None;
        self.conns.lock().unwrap().remove(&conn.id);
    }
}

/// The worker pool + poller driving every connection of one server.
pub(crate) struct EventCore {
    shared: Arc<EventShared>,
    workers: Vec<std::thread::JoinHandle<()>>,
    poll_thread: Option<std::thread::JoinHandle<()>>,
}

impl EventCore {
    pub(crate) fn start(inner: Arc<ServerInner>, threads: usize) -> Result<EventCore> {
        let shared = Arc::new(EventShared {
            inner,
            poller: Poller::new()?,
            ready: Mutex::new(VecDeque::new()),
            ready_cv: Condvar::new(),
            conns: Mutex::new(HashMap::new()),
            timers: Mutex::new(BinaryHeap::new()),
            stop: AtomicBool::new(false),
            next_id: AtomicU64::new(1),
        });
        let threads = threads.max(1);
        let mut workers = Vec::with_capacity(threads);
        for i in 0..threads {
            let s = shared.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("reverb-svc-{i}"))
                    .spawn(move || worker_loop(s))
                    .expect("spawn service worker"),
            );
        }
        let s = shared.clone();
        let poll_thread = std::thread::Builder::new()
            .name("reverb-poll".into())
            .spawn(move || poll_loop(s))
            .expect("spawn poll thread");
        Ok(EventCore {
            shared,
            workers,
            poll_thread: Some(poll_thread),
        })
    }

    pub(crate) fn shared(&self) -> Arc<EventShared> {
        self.shared.clone()
    }

    /// Stop the pool: workers drain the ready queue (so cancel-released
    /// parked ops still get their error replies), then exit; all
    /// connections are then closed.
    pub(crate) fn stop(&mut self) {
        if self.shared.stop.swap(true, Ordering::SeqCst) {
            return;
        }
        self.shared.poller.wake();
        {
            // Lock/unlock pairs with the workers' wait loop so the stop
            // flag is observed.
            drop(self.shared.ready.lock().unwrap());
        }
        self.shared.ready_cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        if let Some(p) = self.poll_thread.take() {
            let _ = p.join();
        }
        let conns: Vec<Arc<EventConn>> = {
            let mut map = self.shared.conns.lock().unwrap();
            map.drain().map(|(_, c)| c).collect()
        };
        for conn in conns {
            let mut st = conn.state.lock().unwrap();
            conn.closed.store(true, Ordering::SeqCst);
            st.stream = Box::new(ClosedStream);
        }
    }
}

impl Drop for EventCore {
    fn drop(&mut self) {
        self.stop();
    }
}

fn worker_loop(shared: Arc<EventShared>) {
    loop {
        let conn = {
            let mut q = shared.ready.lock().unwrap();
            loop {
                if let Some(c) = q.pop_front() {
                    break c;
                }
                if shared.stop.load(Ordering::SeqCst) {
                    return;
                }
                q = shared.ready_cv.wait(q).unwrap();
            }
        };
        conn.queued.store(false, Ordering::SeqCst);
        service(&shared, &conn);
    }
}

fn poll_loop(shared: Arc<EventShared>) {
    while !shared.stop.load(Ordering::SeqCst) {
        // Fire due timers; find the next deadline.
        let now = Instant::now();
        let mut due = Vec::new();
        let mut next: Option<Instant> = None;
        {
            let mut timers = shared.timers.lock().unwrap();
            while let Some(&Reverse((at, id))) = timers.peek() {
                if at <= now {
                    timers.pop();
                    due.push(id);
                } else {
                    next = Some(at);
                    break;
                }
            }
        }
        for id in due {
            let conn = shared.conns.lock().unwrap().get(&id).cloned();
            if let Some(c) = conn {
                shared.schedule(&c);
            }
        }
        let timeout = match next {
            Some(at) => at.saturating_duration_since(now).min(POLL_TICK),
            None => POLL_TICK,
        };
        for token in shared.poller.poll(timeout) {
            let conn = shared.conns.lock().unwrap().get(&token).cloned();
            if let Some(c) = conn {
                shared.schedule(&c);
            }
        }
    }
}

/// One service pass over a connection's state machine.
fn service(shared: &Arc<EventShared>, conn: &Arc<EventConn>) {
    let mut st = conn.state.lock().unwrap();
    if conn.closed.load(Ordering::SeqCst) {
        return;
    }

    // 1. Retry a parked op (wakeup or timer brought us here).
    let mut may_read = true;
    if let Some(op) = st.parked.take() {
        match attempt_parked(shared, &mut st, op) {
            Ok(Attempt::Done) => {}
            Ok(Attempt::Parked(op, kind)) => {
                park(shared, conn, &mut st, op, kind);
                may_read = false;
            }
            Err(_) => {
                shared.close(conn, &mut st);
                return;
            }
        }
        if conn.closed.load(Ordering::SeqCst) {
            return;
        }
    }

    // 2. Resume a partial reply write before producing more output.
    if st.want_write {
        match st.stream.try_flush() {
            Ok(true) => st.want_write = false,
            Ok(false) => {
                shared.arm_write(&st, conn.id);
                return;
            }
            Err(_) => {
                shared.close(conn, &mut st);
                return;
            }
        }
    }

    // 3. Read + dispatch until the input drains (or we park / yield).
    if may_read && st.parked.is_none() {
        let mut frames = 0usize;
        loop {
            if frames >= MAX_FRAMES_PER_SERVICE {
                // Fairness: let other connections at the workers; more
                // input may still be buffered, so come straight back.
                shared.schedule(conn);
                break;
            }
            match st.stream.try_recv() {
                Ok(Some(msg)) => {
                    frames += 1;
                    match dispatch(shared, &mut st, msg) {
                        Ok(Dispatch::Continue) => continue,
                        Ok(Dispatch::Parked(op, kind)) => {
                            park(shared, conn, &mut st, op, kind);
                            break;
                        }
                        Err(_) => {
                            shared.close(conn, &mut st);
                            return;
                        }
                    }
                }
                Ok(None) => {
                    // Input drained: re-arm readiness (fd backends; the
                    // in-proc waker is persistent).
                    shared.arm_read(&st, conn.id);
                    break;
                }
                Err(_) => {
                    // Peer hung up (mid-frame drops land here too).
                    shared.close(conn, &mut st);
                    return;
                }
            }
        }
        if conn.closed.load(Ordering::SeqCst) {
            return;
        }
    }

    // 4. Flush replies produced this pass.
    match st.stream.try_flush() {
        Ok(true) => {}
        Ok(false) => {
            st.want_write = true;
            shared.arm_write(&st, conn.id);
        }
        Err(_) => shared.close(conn, &mut st),
    }
}

/// Park `op` on its wakeup source, then re-attempt once: a notification
/// that fired between the failed attempt and hook registration would
/// otherwise be lost (see `Waiters::add_hook`). The retry timer bounds
/// staleness in every remaining race.
fn park(
    shared: &Arc<EventShared>,
    conn: &Arc<EventConn>,
    st: &mut ConnState,
    op: ParkedOp,
    kind: ParkKind,
) {
    let waker = shared.waker_for(conn);
    let slice = match kind {
        ParkKind::Gate => GATE_SLICE,
        ParkKind::Insert | ParkKind::Sample => PARK_SLICE,
    };
    let retry_at = (Instant::now() + slice).min(op.deadline());
    match (&op, kind) {
        (_, ParkKind::Gate) => shared.inner.gate.register_resume_waker(waker),
        (ParkedOp::Insert { table, .. }, _) => table.register_insert_waker(waker),
        (ParkedOp::Sample { table, .. }, _) => table.register_sample_waker(waker),
    }
    shared.add_timer(retry_at, conn.id);
    match attempt_parked(shared, st, op) {
        Ok(Attempt::Done) => {}
        Ok(Attempt::Parked(op, _)) => st.parked = Some(op),
        Err(_) => shared.close(conn, st),
    }
}

/// Retry a parked op.
fn attempt_parked(shared: &Arc<EventShared>, st: &mut ConnState, op: ParkedOp) -> Result<Attempt> {
    match op {
        ParkedOp::Insert {
            id,
            table,
            item,
            deadline,
            timeout,
            noted,
        } => attempt_insert(shared, st, id, table, item, deadline, timeout, noted),
        ParkedOp::Sample {
            id,
            table,
            n,
            deadline,
            timeout,
            noted,
        } => attempt_sample(shared, st, id, table, n, deadline, timeout, noted),
    }
}

/// One non-blocking insert attempt. The gate guard is held only for the
/// duration of the try — a corridor park never pins a worker *or* holds
/// the gate open.
#[allow(clippy::too_many_arguments)]
fn attempt_insert(
    shared: &Arc<EventShared>,
    st: &mut ConnState,
    id: u64,
    table: Arc<Table>,
    item: Item,
    deadline: Instant,
    timeout: Duration,
    noted: bool,
) -> Result<Attempt> {
    let Some(_guard) = shared.inner.gate.try_enter() else {
        return Ok(Attempt::Parked(
            ParkedOp::Insert {
                id,
                table,
                item,
                deadline,
                timeout,
                noted,
            },
            ParkKind::Gate,
        ));
    };
    match table.try_insert_or_assign(item) {
        Ok(TryInsertOutcome::Inserted) => {
            send_reply(st, id, Ok(String::new()))?;
            Ok(Attempt::Done)
        }
        Ok(TryInsertOutcome::Blocked(item)) => {
            if Instant::now() >= deadline {
                send_reply(st, id, Err(Error::RateLimiterTimeout(timeout)))?;
                return Ok(Attempt::Done);
            }
            if !noted {
                table.note_blocked_insert();
            }
            Ok(Attempt::Parked(
                ParkedOp::Insert {
                    id,
                    table,
                    item,
                    deadline,
                    timeout,
                    noted: true,
                },
                ParkKind::Insert,
            ))
        }
        Err(e) => {
            send_reply(st, id, Err(e))?;
            Ok(Attempt::Done)
        }
    }
}

/// One non-blocking sample attempt (see [`attempt_insert`]).
#[allow(clippy::too_many_arguments)]
fn attempt_sample(
    shared: &Arc<EventShared>,
    st: &mut ConnState,
    id: u64,
    table: Arc<Table>,
    n: usize,
    deadline: Instant,
    timeout: Duration,
    noted: bool,
) -> Result<Attempt> {
    let Some(_guard) = shared.inner.gate.try_enter() else {
        return Ok(Attempt::Parked(
            ParkedOp::Sample {
                id,
                table,
                n,
                deadline,
                timeout,
                noted,
            },
            ParkKind::Gate,
        ));
    };
    match table.try_sample_batch(n) {
        Ok(TrySampleOutcome::Sampled(samples)) => {
            st.stream.send(sample_reply(id, &samples))?;
            Ok(Attempt::Done)
        }
        Ok(TrySampleOutcome::Blocked) => {
            if Instant::now() >= deadline {
                send_err(st, id, &Error::RateLimiterTimeout(timeout))?;
                return Ok(Attempt::Done);
            }
            if !noted {
                table.note_blocked_sample();
            }
            Ok(Attempt::Parked(
                ParkedOp::Sample {
                    id,
                    table,
                    n,
                    deadline,
                    timeout,
                    noted: true,
                },
                ParkKind::Sample,
            ))
        }
        Err(e) => {
            send_err(st, id, &e)?;
            Ok(Attempt::Done)
        }
    }
}

/// Dispatch one inbound frame. `Err` is connection-fatal (reply channel
/// broken or protocol violation); op-level failures become error replies.
fn dispatch(shared: &Arc<EventShared>, st: &mut ConnState, msg: Message) -> Result<Dispatch> {
    match msg {
        Message::InsertChunks { chunks } => {
            stash_chunks(
                &shared.inner,
                &mut st.pending,
                &mut st.pending_order,
                chunks,
            );
            // No reply: chunk streaming is fire-and-forget, acks ride on
            // the subsequent CreateItem.
            Ok(Dispatch::Continue)
        }
        Message::CreateItem { id, item, timeout_ms } => {
            let table = match shared.inner.table(&item.table) {
                Ok(t) => t.clone(),
                Err(e) => {
                    send_reply(st, id, Err(e))?;
                    return Ok(Dispatch::Continue);
                }
            };
            let resolved = match resolve_item(&shared.inner, &st.pending, &item) {
                Ok(i) => i,
                Err(e) => {
                    send_reply(st, id, Err(e))?;
                    return Ok(Dispatch::Continue);
                }
            };
            let timeout = Duration::from_millis(timeout_ms).min(MAX_OP_TIMEOUT);
            let deadline = Instant::now() + timeout;
            match attempt_insert(shared, st, id, table, resolved, deadline, timeout, false)? {
                Attempt::Done => Ok(Dispatch::Continue),
                Attempt::Parked(op, kind) => Ok(Dispatch::Parked(op, kind)),
            }
        }
        Message::SampleRequest {
            id,
            table,
            num_samples,
            timeout_ms,
        } => {
            let table = match shared.inner.table(&table) {
                Ok(t) => t.clone(),
                Err(e) => {
                    send_err(st, id, &e)?;
                    return Ok(Dispatch::Continue);
                }
            };
            let n = num_samples.max(1) as usize;
            let timeout = Duration::from_millis(timeout_ms).min(MAX_OP_TIMEOUT);
            let deadline = Instant::now() + timeout;
            match attempt_sample(shared, st, id, table, n, deadline, timeout, false)? {
                Attempt::Done => Ok(Dispatch::Continue),
                Attempt::Parked(op, kind) => Ok(Dispatch::Parked(op, kind)),
            }
        }
        Message::MutatePriorities {
            id,
            table,
            updates,
            deletes,
        } => {
            let reply = (|| {
                let table = shared.inner.table(&table)?.clone();
                // Mutations never park on the rate limiter; a blocking
                // gate entry is bounded by the (short) checkpoint pause.
                let _guard = shared.inner.gate.enter();
                let updated = table.update_priorities(&updates)?;
                let deleted = table.delete(&deletes)?;
                Ok(format!("updated={updated} deleted={deleted}"))
            })();
            send_reply(st, id, reply)?;
            Ok(Dispatch::Continue)
        }
        Message::Reset { id, table } => {
            let reply = (|| {
                let table = shared.inner.table(&table)?.clone();
                let _guard = shared.inner.gate.enter();
                table.reset();
                Ok(String::new())
            })();
            send_reply(st, id, reply)?;
            Ok(Dispatch::Continue)
        }
        Message::InfoRequest { id } => {
            let tables = shared
                .inner
                .table_order
                .iter()
                .map(|t| (t.name().to_string(), t.info()))
                .collect();
            st.stream.send(Message::Info { id, tables })?;
            Ok(Dispatch::Continue)
        }
        Message::Checkpoint { id } => {
            // Deliberately synchronous on the worker: checkpoints are rare
            // and gate-serialized; parked connections re-arm off the gate's
            // resume hook, so the pause never wedges the pool.
            let reply = shared.inner.checkpoint().map(|p| p.display().to_string());
            send_reply(st, id, reply)?;
            Ok(Dispatch::Continue)
        }
        // Server-to-client messages arriving at the server are protocol
        // violations.
        Message::Ack { .. }
        | Message::Err { .. }
        | Message::SampleData { .. }
        | Message::Info { .. } => Err(Error::Decode("client sent a server-side message".into())),
    }
}

/// Queue an Ack/Err reply (no flush — the service pass flushes once per
/// batch).
fn send_reply(st: &mut ConnState, id: u64, result: Result<String>) -> Result<()> {
    let msg = match result {
        Ok(detail) => Message::Ack { id, detail },
        Err(e) => Message::Err {
            id,
            code: error_code(&e),
            message: e.to_string(),
        },
    };
    st.stream.send(msg)
}

fn send_err(st: &mut ConnState, id: u64, e: &Error) -> Result<()> {
    st.stream.send(Message::Err {
        id,
        code: error_code(e),
        message: e.to_string(),
    })
}

/// Stand-in installed when a connection closes, so the real socket drops
/// (and its fd is returned to the OS) immediately even if the ready queue
/// still holds the connection handle for a moment.
struct ClosedStream;

impl MsgStream for ClosedStream {
    fn send(&mut self, _msg: Message) -> Result<()> {
        Err(closed())
    }
    fn flush(&mut self) -> Result<()> {
        Err(closed())
    }
    fn recv(&mut self) -> Result<Message> {
        Err(closed())
    }
    fn transport(&self) -> &'static str {
        "closed"
    }
    fn set_nonblocking(&mut self, _nonblocking: bool) -> Result<()> {
        Ok(())
    }
    fn poll_source(&self) -> PollSource {
        PollSource::Channel
    }
    fn try_recv(&mut self) -> Result<Option<Message>> {
        Err(closed())
    }
    fn try_flush(&mut self) -> Result<bool> {
        Err(closed())
    }
}

fn closed() -> Error {
    Error::Io(std::io::Error::new(
        std::io::ErrorKind::NotConnected,
        "connection closed",
    ))
}
