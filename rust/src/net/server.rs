//! The Reverb server: tables exposed over the wire protocol through any
//! number of [`TransportListener`]s, with one service thread per connection
//! (Reverb's gRPC server is likewise thread-pooled; contention behaviour
//! lives in the tables, not the transport — see DESIGN.md §2).
//!
//! Every server registers an in-process endpoint (`reverb://in-proc/...`);
//! [`ServerBuilder::bind`] additionally opens a TCP listener, while
//! [`ServerBuilder::serve_in_proc`] serves the in-process path alone.

use crate::core::chunk::Chunk;
use crate::core::chunk_store::ChunkStore;
use crate::core::extensions::TableExtension;
use crate::core::item::Item;
use crate::core::table::{Table, TableConfig, TableInfo};
use crate::error::{Error, Result};
use crate::net::gate::Gate;
use crate::net::transport::{
    self, InProcListener, MsgStream, TcpTransportListener, TransportListener,
};
use crate::net::wire::{error_code, Message, WireItem, WireSampleInfo};
use crate::persist::{PersistConfig, Persister, DEFAULT_SEGMENT_BYTES};
use std::collections::HashMap;
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// How the server persists checkpoints (§3.7 / DESIGN.md §10).
#[derive(Clone, Debug)]
pub enum PersistMode {
    /// Stop-the-world full snapshot per checkpoint — the paper's §3.7
    /// semantics; the gate pause scales with table size.
    Full,
    /// Base snapshot + delta journal + background writer: the checkpoint
    /// gate pause is a constant-time journal rotation, and fsync happens
    /// off the request path.
    Incremental {
        /// Seal journal segments at about this many bytes.
        journal_segment_bytes: usize,
    },
}

impl PersistMode {
    /// Incremental persistence with the default segment size.
    pub fn incremental() -> Self {
        PersistMode::Incremental {
            journal_segment_bytes: DEFAULT_SEGMENT_BYTES,
        }
    }
}

/// Long blocking waits are sliced into segments of this length so the
/// checkpoint gate can drain promptly (see `net::gate`).
const WAIT_SLICE: Duration = Duration::from_millis(50);

/// Per-connection cache of recently streamed chunks awaiting item creation.
/// Bounded; writers create items promptly after streaming chunks.
const PENDING_CHUNK_CAP: usize = 1024;

/// Server construction options.
pub struct ServerBuilder {
    tables: Vec<(TableConfig, Vec<Box<dyn TableExtension>>)>,
    checkpoint_dir: Option<PathBuf>,
    load_checkpoint: Option<PathBuf>,
    checkpoint_interval: Option<Duration>,
    persist_mode: PersistMode,
    in_proc_name: Option<String>,
}

impl ServerBuilder {
    pub fn new() -> Self {
        ServerBuilder {
            tables: Vec::new(),
            checkpoint_dir: None,
            load_checkpoint: None,
            checkpoint_interval: None,
            persist_mode: PersistMode::Full,
            in_proc_name: None,
        }
    }

    /// Add a table.
    pub fn table(mut self, config: TableConfig) -> Self {
        self.tables.push((config, Vec::new()));
        self
    }

    /// Add a table with extensions (§3.5).
    pub fn table_with_extensions(
        mut self,
        config: TableConfig,
        extensions: Vec<Box<dyn TableExtension>>,
    ) -> Self {
        self.tables.push((config, extensions));
        self
    }

    /// Directory for client-triggered checkpoints (§3.7).
    pub fn checkpoint_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.checkpoint_dir = Some(dir.into());
        self
    }

    /// Load this checkpoint at construction time (§3.7).
    pub fn load_checkpoint(mut self, path: impl Into<PathBuf>) -> Self {
        self.load_checkpoint = Some(path.into());
        self
    }

    /// Write a checkpoint automatically every `interval` (§3.7: "potential
    /// data loss ... can be limited through the use of periodic
    /// checkpointing"). Requires [`ServerBuilder::checkpoint_dir`]. Under
    /// [`PersistMode::Incremental`] each tick is a journal rotation +
    /// manifest commit, so short intervals stay cheap.
    pub fn checkpoint_interval(mut self, interval: Duration) -> Self {
        self.checkpoint_interval = Some(interval);
        self
    }

    /// Select the checkpoint persistence mode (default:
    /// [`PersistMode::Full`], the seed's stop-the-world behaviour).
    /// [`PersistMode::Incremental`] requires
    /// [`ServerBuilder::checkpoint_dir`]; if that directory already holds
    /// a manifest from a previous incarnation and no explicit
    /// [`ServerBuilder::load_checkpoint`] was given, the server restores
    /// it automatically before serving (a plain restart never wipes the
    /// durable chain).
    pub fn persist_mode(mut self, mode: PersistMode) -> Self {
        self.persist_mode = mode;
        self
    }

    /// Name the in-process endpoint (default: a process-unique name).
    pub fn in_proc_name(mut self, name: impl Into<String>) -> Self {
        self.in_proc_name = Some(name.into());
        self
    }

    /// Bind a TCP listener on `addr` (use port 0 for an ephemeral port) and
    /// start serving. The in-process endpoint is registered as well.
    pub fn bind(self, addr: &str) -> Result<Server> {
        let tcp = TcpTransportListener::bind(addr)?;
        let local_addr = tcp.local_addr();
        let in_proc_name = self.in_proc_name.clone();
        let in_proc = InProcListener::bind(in_proc_name)?;
        self.start(Some((tcp, local_addr)), in_proc)
    }

    /// Serve the zero-copy in-process transport only — no sockets at all.
    /// Clients connect via [`Server::in_proc_addr`].
    pub fn serve_in_proc(self) -> Result<Server> {
        let in_proc = InProcListener::bind(self.in_proc_name.clone())?;
        self.start(None, in_proc)
    }

    fn start(
        self,
        tcp: Option<(TcpTransportListener, SocketAddr)>,
        in_proc: InProcListener,
    ) -> Result<Server> {
        let mut tables = HashMap::new();
        let mut table_order = Vec::new();
        for (config, extensions) in self.tables {
            let name = config.name.clone();
            let t = Arc::new(Table::with_extensions(config, extensions));
            table_order.push(t.clone());
            if tables.insert(name.clone(), t).is_some() {
                // `in_proc` unbinds itself on drop (token-guarded RAII).
                return Err(Error::InvalidArgument(format!("duplicate table {name}")));
            }
        }
        // Align chunk-store lock granularity with the most-sharded table so
        // InsertChunks never contends on coarser locks than CreateItem.
        let store_shards = table_order
            .iter()
            .map(|t| t.num_shards())
            .max()
            .unwrap_or(1)
            .max(crate::core::chunk_store::DEFAULT_NUM_SHARDS);
        let store = ChunkStore::with_shards(store_shards);
        if let Some(path) = &self.load_checkpoint {
            crate::core::checkpoint::load(path, &table_order, &store)?;
        } else if matches!(self.persist_mode, PersistMode::Incremental { .. }) {
            // Starting the persister rewrites the manifest and garbage-
            // collects the old chain, so an incremental server that finds
            // an existing manifest in its checkpoint_dir MUST restore it
            // first — otherwise a plain restart (no --load) would wipe the
            // very state this subsystem exists to protect.
            if let Some(dir) = &self.checkpoint_dir {
                let manifest = dir.join(crate::persist::MANIFEST_NAME);
                if manifest.exists() {
                    crate::core::checkpoint::load(&manifest, &table_order, &store)?;
                }
            }
        }
        // Incremental persistence attaches after any restore: the journal
        // starts from the fresh base the persister writes at startup.
        let persister = match (&self.persist_mode, &self.checkpoint_dir) {
            (PersistMode::Incremental { journal_segment_bytes }, Some(dir)) => Some(
                Persister::start(
                    PersistConfig::new(dir.clone()).with_segment_bytes(*journal_segment_bytes),
                    &table_order,
                )?,
            ),
            (PersistMode::Incremental { .. }, None) => {
                return Err(Error::InvalidArgument(
                    "incremental persistence requires checkpoint_dir".into(),
                ));
            }
            (PersistMode::Full, _) => None,
        };
        let inner = Arc::new(ServerInner {
            tables,
            table_order,
            store,
            gate: Gate::new(),
            checkpoint_dir: self.checkpoint_dir,
            checkpoint_seq: AtomicU64::new(0),
            persister,
            shutdown: AtomicBool::new(false),
        });

        let in_proc_addr = in_proc.endpoint();
        let in_proc_name = in_proc.name().to_string();
        let mut shutdowns = vec![ListenerShutdown::InProc(in_proc_name)];
        let mut listeners: Vec<Box<dyn TransportListener>> = vec![Box::new(in_proc)];
        let local_addr = tcp.map(|(listener, addr)| {
            shutdowns.push(ListenerShutdown::Tcp(addr));
            listeners.push(Box::new(listener));
            addr
        });

        let mut accept_threads = Vec::with_capacity(listeners.len());
        for listener in listeners {
            let accept_inner = inner.clone();
            accept_threads.push(
                std::thread::Builder::new()
                    .name("reverb-accept".into())
                    .spawn(move || accept_loop(listener, accept_inner))
                    .expect("spawn accept thread"),
            );
        }

        // Periodic checkpointer (§3.7), if configured.
        let checkpoint_thread = self.checkpoint_interval.map(|interval| {
            if inner.checkpoint_dir.is_none() {
                panic!("checkpoint_interval requires checkpoint_dir");
            }
            let ckpt_inner = inner.clone();
            std::thread::Builder::new()
                .name("reverb-ckpt".into())
                .spawn(move || {
                    let tick = Duration::from_millis(25).min(interval);
                    let mut waited = Duration::ZERO;
                    loop {
                        std::thread::sleep(tick);
                        if ckpt_inner.shutdown.load(Ordering::SeqCst) {
                            return;
                        }
                        waited += tick;
                        if waited >= interval {
                            waited = Duration::ZERO;
                            if let Err(e) = ckpt_inner.checkpoint() {
                                log::warn!("periodic checkpoint failed: {e}");
                            }
                        }
                    }
                })
                .expect("spawn checkpoint thread")
        });

        Ok(Server {
            inner,
            local_addr,
            in_proc_addr,
            shutdowns,
            accept_threads,
            checkpoint_thread,
        })
    }
}

impl Default for ServerBuilder {
    fn default() -> Self {
        Self::new()
    }
}

struct ServerInner {
    tables: HashMap<String, Arc<Table>>,
    /// Construction order (stable info/checkpoint ordering).
    table_order: Vec<Arc<Table>>,
    store: ChunkStore,
    gate: Gate,
    checkpoint_dir: Option<PathBuf>,
    checkpoint_seq: AtomicU64,
    /// Incremental persistence (DESIGN.md §10); `None` = legacy full
    /// snapshots.
    persister: Option<Arc<Persister>>,
    shutdown: AtomicBool,
}

/// How to unblock one listener's accept loop on shutdown.
enum ListenerShutdown {
    /// Dummy-connect to wake the blocking `accept`.
    Tcp(SocketAddr),
    /// Unbind the registry entry; the accept channel disconnects.
    InProc(String),
}

/// A running Reverb server. Dropping (or calling [`Server::stop`]) shuts it
/// down and releases all blocked clients.
pub struct Server {
    inner: Arc<ServerInner>,
    local_addr: Option<SocketAddr>,
    in_proc_addr: String,
    shutdowns: Vec<ListenerShutdown>,
    accept_threads: Vec<std::thread::JoinHandle<()>>,
    checkpoint_thread: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Convenience: builder.
    pub fn builder() -> ServerBuilder {
        ServerBuilder::new()
    }

    /// The bound TCP address (e.g. `127.0.0.1:41523`).
    ///
    /// Panics for in-process-only servers ([`ServerBuilder::serve_in_proc`]);
    /// use [`Server::tcp_addr`] to probe.
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
            .expect("server has no TCP listener (in-proc only)")
    }

    /// The bound TCP address, if a TCP listener was requested.
    pub fn tcp_addr(&self) -> Option<SocketAddr> {
        self.local_addr
    }

    /// The in-process endpoint (`reverb://in-proc/<name>`), always
    /// available. Same-process clients connecting here skip
    /// serialization and syscalls entirely.
    pub fn in_proc_addr(&self) -> String {
        self.in_proc_addr.clone()
    }

    /// Direct in-process access to a table — used by benchmarks that want
    /// to isolate table behaviour from transport cost, and by embedded
    /// (single-process) deployments.
    pub fn table(&self, name: &str) -> Result<Arc<Table>> {
        self.inner
            .tables
            .get(name)
            .cloned()
            .ok_or_else(|| Error::TableNotFound(name.into()))
    }

    /// Info for all tables, in construction order.
    pub fn info(&self) -> Vec<(String, TableInfo)> {
        self.inner
            .table_order
            .iter()
            .map(|t| (t.name().to_string(), t.info()))
            .collect()
    }

    /// Write a checkpoint now (also reachable via the client RPC). Under
    /// [`PersistMode::Incremental`] the returned path is the manifest.
    pub fn checkpoint(&self) -> Result<PathBuf> {
        self.inner.checkpoint()
    }

    /// Duration requests were blocked by the most recent checkpoint's
    /// §3.7 gate pause — constant under [`PersistMode::Incremental`],
    /// table-size-proportional under [`PersistMode::Full`]
    /// (`benches/checkpoint_pause.rs`).
    pub fn last_checkpoint_pause(&self) -> Duration {
        self.inner.gate.last_pause()
    }

    /// Stop serving: wake blocked clients, close the listeners, join.
    pub fn stop(&mut self) {
        if self.inner.shutdown.swap(true, Ordering::SeqCst) {
            return;
        }
        for t in &self.inner.table_order {
            t.cancel();
        }
        for s in &self.shutdowns {
            match s {
                // Unblock the accept loop.
                ListenerShutdown::Tcp(addr) => {
                    let _ = TcpStream::connect(addr);
                }
                ListenerShutdown::InProc(name) => transport::in_proc_unbind(name),
            }
        }
        for h in self.accept_threads.drain(..) {
            let _ = h.join();
        }
        if let Some(h) = self.checkpoint_thread.take() {
            let _ = h.join();
        }
        // Final journal rotation + durable manifest, then join the
        // background writer.
        if let Some(p) = &self.inner.persister {
            p.stop(&self.inner.table_order);
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop();
    }
}

impl ServerInner {
    fn table(&self, name: &str) -> Result<&Arc<Table>> {
        self.tables
            .get(name)
            .ok_or_else(|| Error::TableNotFound(name.into()))
    }

    fn checkpoint(&self) -> Result<PathBuf> {
        if let Some(persister) = &self.persister {
            // Incremental (§3.7 revisited, DESIGN.md §10): the pause only
            // covers draining in-flight handlers plus a constant-time
            // journal rotation — independent of table size. Durability
            // (segment spill + manifest fsync) is awaited after the gate
            // has reopened, on the background writer.
            self.gate.pause();
            let pending = persister.rotate(&self.table_order);
            self.gate.resume();
            return pending.wait();
        }
        let dir = self
            .checkpoint_dir
            .clone()
            .ok_or_else(|| Error::InvalidArgument("server has no checkpoint_dir".into()))?;
        // Block all incoming requests for the duration (§3.7).
        self.gate.pause();
        let result = (|| {
            let seq = self.checkpoint_seq.fetch_add(1, Ordering::SeqCst);
            let path = dir.join(format!("ckpt_{seq:06}.rvb"));
            crate::core::checkpoint::save(&path, &self.table_order)?;
            Ok(path)
        })();
        self.gate.resume();
        result
    }

    /// Insert with gate-sliced blocking (see WAIT_SLICE). The item is
    /// cloned per attempt (cheap: `Arc<Chunk>` refs + metadata) so a sliced
    /// timeout can retry after re-entering the gate.
    fn gated_insert(&self, table: &Arc<Table>, item: Item, timeout: Duration) -> Result<()> {
        let deadline = Instant::now() + timeout;
        loop {
            let _guard = self.gate.enter();
            let now = Instant::now();
            let slice = WAIT_SLICE.min(deadline.saturating_duration_since(now));
            match table.insert_or_assign(item.clone(), Some(slice)) {
                Ok(()) => return Ok(()),
                Err(Error::RateLimiterTimeout(_)) if Instant::now() < deadline => continue,
                Err(e) => return Err(e),
            }
        }
    }

    /// Sample with gate-sliced blocking.
    fn gated_sample(
        &self,
        table: &Arc<Table>,
        n: usize,
        timeout: Duration,
    ) -> Result<Vec<crate::core::item::SampledItem>> {
        let deadline = Instant::now() + timeout;
        loop {
            let _guard = self.gate.enter();
            let now = Instant::now();
            let slice = WAIT_SLICE.min(deadline.saturating_duration_since(now));
            match table.sample_batch(n, Some(slice)) {
                Ok(items) => return Ok(items),
                Err(Error::RateLimiterTimeout(_)) if Instant::now() < deadline => continue,
                Err(e) => return Err(e),
            }
        }
    }
}

fn accept_loop(mut listener: Box<dyn TransportListener>, inner: Arc<ServerInner>) {
    loop {
        match listener.accept() {
            Ok(Some(stream)) => {
                if inner.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                let conn_inner = inner.clone();
                let _ = std::thread::Builder::new()
                    .name("reverb-conn".into())
                    .spawn(move || {
                        let _ = serve_connection(stream, conn_inner);
                    });
            }
            // Listener closed cleanly (in-proc unbind).
            Ok(None) => return,
            Err(_) => {
                if inner.shutdown.load(Ordering::SeqCst) {
                    return;
                }
            }
        }
    }
}

/// Build a table `Item` from its wire form, resolving chunk references from
/// the per-connection pending set or the global store. Trajectory items
/// (v2 frames) are validated per column against the resolved chunks:
/// `Item::new_trajectory` rejects slices that overrun a chunk, reference a
/// chunk the item does not carry, or gather from multi-field chunks.
fn resolve_item(
    inner: &ServerInner,
    pending: &HashMap<u64, Arc<Chunk>>,
    wire: &WireItem,
) -> Result<Item> {
    let chunks = wire
        .chunk_keys
        .iter()
        .map(|k| {
            pending
                .get(k)
                .cloned()
                .map(Ok)
                .unwrap_or_else(|| inner.store.get(*k))
        })
        .collect::<Result<Vec<_>>>()?;
    match &wire.columns {
        Some(columns) => Item::new_trajectory_shared(
            wire.key,
            wire.table.clone(),
            wire.priority,
            chunks,
            columns.clone(),
        ),
        None => Item::new(
            wire.key,
            wire.table.clone(),
            wire.priority,
            chunks,
            wire.offset as usize,
            wire.length as usize,
        ),
    }
}

/// Convert a sampled item to its wire form plus its chunk set.
fn sampled_to_wire(s: &crate::core::item::SampledItem) -> (WireSampleInfo, Vec<Arc<Chunk>>) {
    let info = WireSampleInfo {
        item: WireItem {
            key: s.item.key,
            table: s.item.table.clone(),
            priority: s.item.priority,
            chunk_keys: s.item.chunks.iter().map(|c| c.key).collect(),
            offset: s.item.offset as u64,
            length: s.item.length as u64,
            times_sampled: s.item.times_sampled,
            columns: s.item.columns.clone(),
        },
        probability: s.probability,
        table_size: s.table_size as u64,
    };
    (info, s.item.chunks.clone())
}

fn serve_connection(mut stream: Box<dyn MsgStream>, inner: Arc<ServerInner>) -> Result<()> {
    // Chunks streamed on this connection, awaiting item creation. On the
    // in-process transport these are the writer's own allocations — the
    // whole insert path is copy-free from client append to table item.
    let mut pending: HashMap<u64, Arc<Chunk>> = HashMap::new();
    let mut pending_order: std::collections::VecDeque<u64> = std::collections::VecDeque::new();

    loop {
        if inner.shutdown.load(Ordering::SeqCst) {
            return Ok(());
        }
        let msg = match stream.recv() {
            Ok(m) => m,
            Err(Error::Io(_)) => return Ok(()), // client hung up
            Err(e) => return Err(e),
        };
        match msg {
            Message::InsertChunks { chunks } => {
                for chunk in chunks {
                    let key = chunk.key;
                    let arc = inner.store.insert_arc(chunk);
                    if pending.insert(key, arc).is_none() {
                        pending_order.push_back(key);
                    }
                    while pending_order.len() > PENDING_CHUNK_CAP {
                        if let Some(old) = pending_order.pop_front() {
                            pending.remove(&old);
                        }
                    }
                }
                // No reply: chunk streaming is fire-and-forget, acks ride
                // on the subsequent CreateItem.
            }
            Message::CreateItem { id, item, timeout_ms } => {
                let reply = (|| {
                    let table = inner.table(&item.table)?.clone();
                    let item = resolve_item(&inner, &pending, &item)?;
                    inner.gated_insert(&table, item, Duration::from_millis(timeout_ms))?;
                    Ok(())
                })();
                send_reply(stream.as_mut(), id, reply.map(|()| String::new()))?;
            }
            Message::SampleRequest {
                id,
                table,
                num_samples,
                timeout_ms,
            } => {
                let result = (|| {
                    let table = inner.table(&table)?.clone();
                    inner.gated_sample(
                        &table,
                        num_samples.max(1) as usize,
                        Duration::from_millis(timeout_ms),
                    )
                })();
                match result {
                    Ok(samples) => {
                        let mut infos = Vec::with_capacity(samples.len());
                        let mut chunks: Vec<Arc<Chunk>> = Vec::with_capacity(samples.len());
                        for s in &samples {
                            let (info, item_chunks) = sampled_to_wire(s);
                            infos.push(info);
                            for c in item_chunks {
                                // Dedup chunks shared across items in this
                                // response batch. The response carries the
                                // shared handles: TCP encodes straight from
                                // them, in-proc hands them to the client
                                // as-is — no payload clone either way (hot
                                // path). Linear scan beats a HashSet at
                                // batch sizes.
                                if !chunks.iter().any(|x| x.key == c.key) {
                                    chunks.push(c);
                                }
                            }
                        }
                        stream.send(Message::SampleData { id, infos, chunks })?;
                        stream.flush()?;
                    }
                    Err(e) => {
                        send_err(stream.as_mut(), id, &e)?;
                    }
                }
            }
            Message::MutatePriorities {
                id,
                table,
                updates,
                deletes,
            } => {
                let reply = (|| {
                    let table = inner.table(&table)?.clone();
                    let _guard = inner.gate.enter();
                    let updated = table.update_priorities(&updates)?;
                    let deleted = table.delete(&deletes)?;
                    Ok(format!("updated={updated} deleted={deleted}"))
                })();
                send_reply(stream.as_mut(), id, reply)?;
            }
            Message::Reset { id, table } => {
                let reply = (|| {
                    let table = inner.table(&table)?.clone();
                    let _guard = inner.gate.enter();
                    table.reset();
                    Ok(String::new())
                })();
                send_reply(stream.as_mut(), id, reply)?;
            }
            Message::InfoRequest { id } => {
                let tables = inner
                    .table_order
                    .iter()
                    .map(|t| (t.name().to_string(), t.info()))
                    .collect();
                stream.send(Message::Info { id, tables })?;
                stream.flush()?;
            }
            Message::Checkpoint { id } => {
                let reply = inner
                    .checkpoint()
                    .map(|p| p.display().to_string());
                send_reply(stream.as_mut(), id, reply)?;
            }
            // Server-to-client messages arriving at the server are protocol
            // violations.
            Message::Ack { .. }
            | Message::Err { .. }
            | Message::SampleData { .. }
            | Message::Info { .. } => {
                return Err(Error::Decode("client sent a server-side message".into()));
            }
        }
    }
}

fn send_reply(stream: &mut dyn MsgStream, id: u64, result: Result<String>) -> Result<()> {
    let msg = match result {
        Ok(detail) => Message::Ack { id, detail },
        Err(e) => Message::Err {
            id,
            code: error_code(&e),
            message: e.to_string(),
        },
    };
    stream.send(msg)?;
    stream.flush()
}

fn send_err(stream: &mut dyn MsgStream, id: u64, e: &Error) -> Result<()> {
    stream.send(Message::Err {
        id,
        code: error_code(e),
        message: e.to_string(),
    })?;
    stream.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::chunk::Compression;
    use crate::core::tensor::Tensor;
    use crate::net::wire::Message;
    use std::io::{BufReader, BufWriter, Write};

    fn mk_chunk(key: u64, v: f32) -> Arc<Chunk> {
        let steps = vec![vec![Tensor::from_f32(&[1], &[v]).unwrap()]];
        Arc::new(Chunk::from_steps(key, 0, &steps, Compression::None).unwrap())
    }

    fn start_server() -> Server {
        Server::builder()
            .table(TableConfig::uniform_replay("replay", 100))
            .table(TableConfig::queue("queue", 4))
            .bind("127.0.0.1:0")
            .unwrap()
    }

    /// Raw-protocol round trip over plain TCP framing (the typed Client is
    /// tested in client/; both transports are covered by the conformance
    /// suite in tests/transport_conformance.rs).
    #[test]
    fn raw_insert_then_sample_over_tcp() {
        let server = start_server();
        let stream = TcpStream::connect(server.local_addr()).unwrap();
        stream.set_nodelay(true).unwrap();
        let mut r = BufReader::new(stream.try_clone().unwrap());
        let mut w = BufWriter::new(stream);

        Message::InsertChunks {
            chunks: vec![mk_chunk(11, 3.5)],
        }
        .write_frame(&mut w)
        .unwrap();
        Message::CreateItem {
            id: 1,
            item: WireItem {
                key: 7,
                table: "replay".into(),
                priority: 1.0,
                chunk_keys: vec![11],
                offset: 0,
                length: 1,
                times_sampled: 0,
                columns: None,
            },
            timeout_ms: 1000,
        }
        .write_frame(&mut w)
        .unwrap();
        w.flush().unwrap();
        match Message::read_frame(&mut r).unwrap() {
            Message::Ack { id, .. } => assert_eq!(id, 1),
            other => panic!("expected ack, got {other:?}"),
        }

        Message::SampleRequest {
            id: 2,
            table: "replay".into(),
            num_samples: 1,
            timeout_ms: 1000,
        }
        .write_frame(&mut w)
        .unwrap();
        w.flush().unwrap();
        match Message::read_frame(&mut r).unwrap() {
            Message::SampleData { id, infos, chunks } => {
                assert_eq!(id, 2);
                assert_eq!(infos[0].item.key, 7);
                assert_eq!(chunks[0].key, 11);
                let steps = chunks[0].to_steps().unwrap();
                assert_eq!(steps[0][0].to_f32().unwrap(), vec![3.5]);
            }
            other => panic!("expected samples, got {other:?}"),
        }
    }

    /// The same raw round trip over the in-process transport, proving both
    /// backends speak the identical protocol — and that the sampled chunk
    /// is the very allocation the server holds (zero-copy).
    #[test]
    fn raw_insert_then_sample_in_proc() {
        let server = start_server();
        let mut conn = transport::dial(&server.in_proc_addr()).unwrap();
        let sent = mk_chunk(21, 9.25);
        conn.send(Message::InsertChunks {
            chunks: vec![sent.clone()],
        })
        .unwrap();
        conn.send(Message::CreateItem {
            id: 1,
            item: WireItem {
                key: 9,
                table: "replay".into(),
                priority: 1.0,
                chunk_keys: vec![21],
                offset: 0,
                length: 1,
                times_sampled: 0,
                columns: None,
            },
            timeout_ms: 1000,
        })
        .unwrap();
        conn.flush().unwrap();
        match conn.recv().unwrap() {
            Message::Ack { id, .. } => assert_eq!(id, 1),
            other => panic!("expected ack, got {other:?}"),
        }

        conn.send(Message::SampleRequest {
            id: 2,
            table: "replay".into(),
            num_samples: 1,
            timeout_ms: 1000,
        })
        .unwrap();
        conn.flush().unwrap();
        match conn.recv().unwrap() {
            Message::SampleData { id, infos, chunks } => {
                assert_eq!(id, 2);
                assert_eq!(infos[0].item.key, 9);
                assert!(
                    Arc::ptr_eq(&chunks[0], &sent),
                    "in-proc sample must share the inserted chunk allocation"
                );
            }
            other => panic!("expected samples, got {other:?}"),
        }
    }

    #[test]
    fn in_proc_only_server_serves_and_reports_no_tcp() {
        let server = Server::builder()
            .table(TableConfig::uniform_replay("t", 10))
            .serve_in_proc()
            .unwrap();
        assert!(server.tcp_addr().is_none());
        let mut conn = transport::dial(&server.in_proc_addr()).unwrap();
        conn.send(Message::InfoRequest { id: 5 }).unwrap();
        conn.flush().unwrap();
        match conn.recv().unwrap() {
            Message::Info { tables, .. } => assert_eq!(tables[0].0, "t"),
            other => panic!("expected info, got {other:?}"),
        }
    }

    #[test]
    fn unknown_table_errors() {
        let server = start_server();
        let stream = TcpStream::connect(server.local_addr()).unwrap();
        let mut r = BufReader::new(stream.try_clone().unwrap());
        let mut w = BufWriter::new(stream);
        Message::SampleRequest {
            id: 1,
            table: "nope".into(),
            num_samples: 1,
            timeout_ms: 10,
        }
        .write_frame(&mut w)
        .unwrap();
        w.flush().unwrap();
        match Message::read_frame(&mut r).unwrap() {
            Message::Err { code, .. } => assert_eq!(code, crate::net::wire::code::NOT_FOUND),
            other => panic!("expected err, got {other:?}"),
        }
    }

    #[test]
    fn sample_timeout_maps_to_timeout_code() {
        let server = start_server();
        let stream = TcpStream::connect(server.local_addr()).unwrap();
        let mut r = BufReader::new(stream.try_clone().unwrap());
        let mut w = BufWriter::new(stream);
        Message::SampleRequest {
            id: 1,
            table: "replay".into(),
            num_samples: 1,
            timeout_ms: 30,
        }
        .write_frame(&mut w)
        .unwrap();
        w.flush().unwrap();
        match Message::read_frame(&mut r).unwrap() {
            Message::Err { code, .. } => assert_eq!(code, crate::net::wire::code::TIMEOUT),
            other => panic!("expected timeout, got {other:?}"),
        }
    }

    #[test]
    fn info_request_reports_tables() {
        let server = start_server();
        let stream = TcpStream::connect(server.local_addr()).unwrap();
        let mut r = BufReader::new(stream.try_clone().unwrap());
        let mut w = BufWriter::new(stream);
        Message::InfoRequest { id: 5 }.write_frame(&mut w).unwrap();
        w.flush().unwrap();
        match Message::read_frame(&mut r).unwrap() {
            Message::Info { tables, .. } => {
                let names: Vec<&str> = tables.iter().map(|(n, _)| n.as_str()).collect();
                assert_eq!(names, vec!["replay", "queue"]);
            }
            other => panic!("expected info, got {other:?}"),
        }
    }

    #[test]
    fn stop_releases_blocked_clients() {
        let mut server = start_server();
        let addr = server.local_addr();
        let h = std::thread::spawn(move || {
            let stream = TcpStream::connect(addr).unwrap();
            let mut r = BufReader::new(stream.try_clone().unwrap());
            let mut w = BufWriter::new(stream);
            Message::SampleRequest {
                id: 1,
                table: "replay".into(),
                num_samples: 1,
                timeout_ms: 60_000,
            }
            .write_frame(&mut w)
            .unwrap();
            w.flush().unwrap();
            Message::read_frame(&mut r)
        });
        std::thread::sleep(Duration::from_millis(50));
        server.stop();
        match h.join().unwrap() {
            Ok(Message::Err { code, .. }) => {
                assert_eq!(code, crate::net::wire::code::CANCELLED)
            }
            Ok(other) => panic!("unexpected {other:?}"),
            // Connection torn down before the reply is also acceptable.
            Err(Error::Io(_)) => {}
            Err(e) => panic!("unexpected error {e}"),
        }
    }

    #[test]
    fn stop_unbinds_in_proc_endpoint() {
        let mut server = Server::builder()
            .table(TableConfig::uniform_replay("t", 10))
            .serve_in_proc()
            .unwrap();
        let addr = server.in_proc_addr();
        assert!(transport::dial(&addr).is_ok());
        server.stop();
        assert!(transport::dial(&addr).is_err(), "endpoint must be unbound");
    }

    #[test]
    fn periodic_checkpointing_writes_files() {
        let dir = std::env::temp_dir().join(format!("reverb_periodic_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let server = Server::builder()
            .table(TableConfig::uniform_replay("t", 10))
            .checkpoint_dir(&dir)
            .checkpoint_interval(Duration::from_millis(60))
            .bind("127.0.0.1:0")
            .unwrap();
        // Write something so the checkpoints have content.
        let table = server.table("t").unwrap();
        let steps = vec![vec![crate::core::tensor::Tensor::from_f32(&[1], &[1.0]).unwrap()]];
        let chunk = std::sync::Arc::new(
            Chunk::from_steps(1, 0, &steps, Compression::None).unwrap(),
        );
        table
            .insert_or_assign(
                crate::core::item::Item::new(1, "t", 1.0, vec![chunk], 0, 1).unwrap(),
                None,
            )
            .unwrap();
        std::thread::sleep(Duration::from_millis(250));
        drop(server);
        let ckpts: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.path().extension().is_some_and(|x| x == "rvb"))
            .collect();
        assert!(ckpts.len() >= 2, "expected periodic checkpoints, got {}", ckpts.len());
        // And the newest one restores.
        let newest = ckpts.iter().map(|e| e.path()).max().unwrap();
        let restored = Server::builder()
            .table(TableConfig::uniform_replay("t", 10))
            .load_checkpoint(newest)
            .bind("127.0.0.1:0")
            .unwrap();
        assert_eq!(restored.table("t").unwrap().size(), 1);
        std::fs::remove_dir_all(dir).ok();
    }

    fn mk_flat_item(key: u64, table: &str, priority: f64) -> crate::core::item::Item {
        crate::core::item::Item::new(
            key,
            table,
            priority,
            vec![mk_chunk(key + 500, key as f32)],
            0,
            1,
        )
        .unwrap()
    }

    #[test]
    fn incremental_checkpoint_restores_through_standard_load() {
        let dir = std::env::temp_dir().join(format!(
            "reverb_persist_srv_{}",
            std::process::id()
        ));
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        let server = Server::builder()
            .table(TableConfig::uniform_replay("t", 100))
            .checkpoint_dir(&dir)
            .persist_mode(PersistMode::incremental())
            .serve_in_proc()
            .unwrap();
        let table = server.table("t").unwrap();
        for k in 1..=10 {
            table
                .insert_or_assign(mk_flat_item(k, "t", k as f64), None)
                .unwrap();
        }
        table.update_priorities(&[(3, 99.0)]).unwrap();
        table.delete(&[5]).unwrap();
        let manifest = server.checkpoint().unwrap();
        assert!(manifest.ends_with(crate::persist::MANIFEST_NAME));
        // A mutation after the manifest commit becomes durable via the
        // final rotation at shutdown.
        table
            .insert_or_assign(mk_flat_item(11, "t", 1.0), None)
            .unwrap();
        drop(server);

        let restored = Server::builder()
            .table(TableConfig::uniform_replay("t", 100))
            .load_checkpoint(dir.join(crate::persist::MANIFEST_NAME))
            .serve_in_proc()
            .unwrap();
        let rt = restored.table("t").unwrap();
        assert_eq!(rt.size(), 10, "10 inserts - 1 delete + 1 late insert");
        assert!(!rt.contains(5));
        assert!(rt.contains(11));
        let (items, inserts, _samples) = rt.snapshot();
        assert_eq!(inserts, 11, "insert counter restored exactly");
        let p3 = items.iter().find(|i| i.key == 3).unwrap();
        assert_eq!(p3.priority, 99.0, "priority update replayed");
        // Payloads decode after restore.
        let s = rt.sample(None).unwrap();
        assert!(s.item.materialize().is_ok());
        drop(restored);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn incremental_restart_without_load_restores_automatically() {
        let dir = std::env::temp_dir().join(format!(
            "reverb_persist_autorestore_{}",
            std::process::id()
        ));
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        let mk = || {
            Server::builder()
                .table(TableConfig::uniform_replay("t", 100))
                .checkpoint_dir(&dir)
                .persist_mode(PersistMode::incremental())
                .serve_in_proc()
                .unwrap()
        };
        let server = mk();
        let table = server.table("t").unwrap();
        for k in 1..=3 {
            table
                .insert_or_assign(mk_flat_item(k, "t", 1.0), None)
                .unwrap();
        }
        server.checkpoint().unwrap();
        drop(server);
        // A plain restart (same flags, no explicit load) must restore the
        // chain rather than wipe it.
        let restarted = mk();
        assert_eq!(restarted.table("t").unwrap().size(), 3);
        drop(restarted);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn incremental_requires_checkpoint_dir() {
        let r = Server::builder()
            .table(TableConfig::uniform_replay("t", 10))
            .persist_mode(PersistMode::incremental())
            .serve_in_proc();
        assert!(r.is_err());
    }

    #[test]
    fn periodic_incremental_commits_manifest() {
        let dir = std::env::temp_dir().join(format!(
            "reverb_persist_periodic_{}",
            std::process::id()
        ));
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        let server = Server::builder()
            .table(TableConfig::uniform_replay("t", 10))
            .checkpoint_dir(&dir)
            .persist_mode(PersistMode::incremental())
            .checkpoint_interval(Duration::from_millis(60))
            .serve_in_proc()
            .unwrap();
        let table = server.table("t").unwrap();
        table
            .insert_or_assign(mk_flat_item(1, "t", 1.0), None)
            .unwrap();
        std::thread::sleep(Duration::from_millis(300));
        let m = crate::persist::manifest::read_manifest(
            &dir.join(crate::persist::MANIFEST_NAME),
        )
        .unwrap();
        assert!(m.watermark >= 1, "periodic rotation committed the insert");
        drop(server);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn duplicate_table_rejected() {
        let r = Server::builder()
            .table(TableConfig::uniform_replay("t", 10))
            .table(TableConfig::uniform_replay("t", 10))
            .bind("127.0.0.1:0");
        assert!(r.is_err());
    }

    #[test]
    fn named_in_proc_endpoint_and_duplicate_name_rejected() {
        let server = Server::builder()
            .table(TableConfig::uniform_replay("t", 10))
            .in_proc_name("named-endpoint-test")
            .serve_in_proc()
            .unwrap();
        assert_eq!(
            server.in_proc_addr(),
            format!("{}named-endpoint-test", crate::net::transport::IN_PROC_SCHEME)
        );
        let dup = Server::builder()
            .table(TableConfig::uniform_replay("t", 10))
            .in_proc_name("named-endpoint-test")
            .serve_in_proc();
        assert!(dup.is_err());
    }
}
