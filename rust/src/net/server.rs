//! The Reverb server: a TCP listener exposing tables over the wire
//! protocol, with one service thread per connection (Reverb's gRPC server
//! is likewise thread-pooled; contention behaviour lives in the tables, not
//! the transport — see DESIGN.md §2).

use crate::core::chunk::Chunk;
use crate::core::chunk_store::ChunkStore;
use crate::core::extensions::TableExtension;
use crate::core::item::Item;
use crate::core::table::{Table, TableConfig, TableInfo};
use crate::error::{Error, Result};
use crate::net::gate::Gate;
use crate::net::wire::{error_code, Message, WireItem, WireSampleInfo};
use std::collections::HashMap;
use std::io::{BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Long blocking waits are sliced into segments of this length so the
/// checkpoint gate can drain promptly (see `net::gate`).
const WAIT_SLICE: Duration = Duration::from_millis(50);

/// Per-connection cache of recently streamed chunks awaiting item creation.
/// Bounded; writers create items promptly after streaming chunks.
const PENDING_CHUNK_CAP: usize = 1024;

/// Server construction options.
pub struct ServerBuilder {
    tables: Vec<(TableConfig, Vec<Box<dyn TableExtension>>)>,
    checkpoint_dir: Option<PathBuf>,
    load_checkpoint: Option<PathBuf>,
    checkpoint_interval: Option<Duration>,
}

impl ServerBuilder {
    pub fn new() -> Self {
        ServerBuilder {
            tables: Vec::new(),
            checkpoint_dir: None,
            load_checkpoint: None,
            checkpoint_interval: None,
        }
    }

    /// Add a table.
    pub fn table(mut self, config: TableConfig) -> Self {
        self.tables.push((config, Vec::new()));
        self
    }

    /// Add a table with extensions (§3.5).
    pub fn table_with_extensions(
        mut self,
        config: TableConfig,
        extensions: Vec<Box<dyn TableExtension>>,
    ) -> Self {
        self.tables.push((config, extensions));
        self
    }

    /// Directory for client-triggered checkpoints (§3.7).
    pub fn checkpoint_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.checkpoint_dir = Some(dir.into());
        self
    }

    /// Load this checkpoint at construction time (§3.7).
    pub fn load_checkpoint(mut self, path: impl Into<PathBuf>) -> Self {
        self.load_checkpoint = Some(path.into());
        self
    }

    /// Write a checkpoint automatically every `interval` (§3.7: "potential
    /// data loss ... can be limited through the use of periodic
    /// checkpointing"). Requires [`ServerBuilder::checkpoint_dir`].
    pub fn checkpoint_interval(mut self, interval: Duration) -> Self {
        self.checkpoint_interval = Some(interval);
        self
    }

    /// Bind to `addr` (use port 0 for an ephemeral port) and start serving.
    pub fn bind(self, addr: &str) -> Result<Server> {
        let mut tables = HashMap::new();
        let mut table_order = Vec::new();
        for (config, extensions) in self.tables {
            let name = config.name.clone();
            let t = Arc::new(Table::with_extensions(config, extensions));
            table_order.push(t.clone());
            if tables.insert(name.clone(), t).is_some() {
                return Err(Error::InvalidArgument(format!("duplicate table {name}")));
            }
        }
        let store = ChunkStore::new();
        if let Some(path) = &self.load_checkpoint {
            crate::core::checkpoint::load(path, &table_order, &store)?;
        }
        let inner = Arc::new(ServerInner {
            tables,
            table_order,
            store,
            gate: Gate::new(),
            checkpoint_dir: self.checkpoint_dir,
            checkpoint_seq: AtomicU64::new(0),
            shutdown: AtomicBool::new(false),
        });

        let listener = TcpListener::bind(addr)?;
        let local_addr = listener.local_addr()?;
        let accept_inner = inner.clone();
        let accept_thread = std::thread::Builder::new()
            .name("reverb-accept".into())
            .spawn(move || accept_loop(listener, accept_inner))
            .expect("spawn accept thread");

        // Periodic checkpointer (§3.7), if configured.
        let checkpoint_thread = self.checkpoint_interval.map(|interval| {
            if inner.checkpoint_dir.is_none() {
                panic!("checkpoint_interval requires checkpoint_dir");
            }
            let ckpt_inner = inner.clone();
            std::thread::Builder::new()
                .name("reverb-ckpt".into())
                .spawn(move || {
                    let tick = Duration::from_millis(25).min(interval);
                    let mut waited = Duration::ZERO;
                    loop {
                        std::thread::sleep(tick);
                        if ckpt_inner.shutdown.load(Ordering::SeqCst) {
                            return;
                        }
                        waited += tick;
                        if waited >= interval {
                            waited = Duration::ZERO;
                            if let Err(e) = ckpt_inner.checkpoint() {
                                log::warn!("periodic checkpoint failed: {e}");
                            }
                        }
                    }
                })
                .expect("spawn checkpoint thread")
        });

        Ok(Server {
            inner,
            local_addr,
            accept_thread: Some(accept_thread),
            checkpoint_thread,
        })
    }
}

impl Default for ServerBuilder {
    fn default() -> Self {
        Self::new()
    }
}

struct ServerInner {
    tables: HashMap<String, Arc<Table>>,
    /// Construction order (stable info/checkpoint ordering).
    table_order: Vec<Arc<Table>>,
    store: ChunkStore,
    gate: Gate,
    checkpoint_dir: Option<PathBuf>,
    checkpoint_seq: AtomicU64,
    shutdown: AtomicBool,
}

/// A running Reverb server. Dropping (or calling [`Server::stop`]) shuts it
/// down and releases all blocked clients.
pub struct Server {
    inner: Arc<ServerInner>,
    local_addr: SocketAddr,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    checkpoint_thread: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Convenience: builder.
    pub fn builder() -> ServerBuilder {
        ServerBuilder::new()
    }

    /// The bound address (e.g. `127.0.0.1:41523`).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Direct in-process access to a table — used by benchmarks that want
    /// to isolate table behaviour from transport cost, and by embedded
    /// (single-process) deployments.
    pub fn table(&self, name: &str) -> Result<Arc<Table>> {
        self.inner
            .tables
            .get(name)
            .cloned()
            .ok_or_else(|| Error::TableNotFound(name.into()))
    }

    /// Info for all tables, in construction order.
    pub fn info(&self) -> Vec<(String, TableInfo)> {
        self.inner
            .table_order
            .iter()
            .map(|t| (t.name().to_string(), t.info()))
            .collect()
    }

    /// Write a checkpoint now (also reachable via the client RPC).
    pub fn checkpoint(&self) -> Result<PathBuf> {
        self.inner.checkpoint()
    }

    /// Stop serving: wake blocked clients, close the listener, join.
    pub fn stop(&mut self) {
        if self.inner.shutdown.swap(true, Ordering::SeqCst) {
            return;
        }
        for t in &self.inner.table_order {
            t.cancel();
        }
        // Unblock the accept loop.
        let _ = TcpStream::connect(self.local_addr);
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
        if let Some(h) = self.checkpoint_thread.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop();
    }
}

impl ServerInner {
    fn table(&self, name: &str) -> Result<&Arc<Table>> {
        self.tables
            .get(name)
            .ok_or_else(|| Error::TableNotFound(name.into()))
    }

    fn checkpoint(&self) -> Result<PathBuf> {
        let dir = self
            .checkpoint_dir
            .clone()
            .ok_or_else(|| Error::InvalidArgument("server has no checkpoint_dir".into()))?;
        // Block all incoming requests for the duration (§3.7).
        self.gate.pause();
        let result = (|| {
            let seq = self.checkpoint_seq.fetch_add(1, Ordering::SeqCst);
            let path = dir.join(format!("ckpt_{seq:06}.rvb"));
            crate::core::checkpoint::save(&path, &self.table_order)?;
            Ok(path)
        })();
        self.gate.resume();
        result
    }

    /// Insert with gate-sliced blocking (see WAIT_SLICE). The item is
    /// cloned per attempt (cheap: `Arc<Chunk>` refs + metadata) so a sliced
    /// timeout can retry after re-entering the gate.
    fn gated_insert(&self, table: &Arc<Table>, item: Item, timeout: Duration) -> Result<()> {
        let deadline = Instant::now() + timeout;
        loop {
            let _guard = self.gate.enter();
            let now = Instant::now();
            let slice = WAIT_SLICE.min(deadline.saturating_duration_since(now));
            match table.insert_or_assign(item.clone(), Some(slice)) {
                Ok(()) => return Ok(()),
                Err(Error::RateLimiterTimeout(_)) if Instant::now() < deadline => continue,
                Err(e) => return Err(e),
            }
        }
    }

    /// Sample with gate-sliced blocking.
    fn gated_sample(
        &self,
        table: &Arc<Table>,
        n: usize,
        timeout: Duration,
    ) -> Result<Vec<crate::core::item::SampledItem>> {
        let deadline = Instant::now() + timeout;
        loop {
            let _guard = self.gate.enter();
            let now = Instant::now();
            let slice = WAIT_SLICE.min(deadline.saturating_duration_since(now));
            match table.sample_batch(n, Some(slice)) {
                Ok(items) => return Ok(items),
                Err(Error::RateLimiterTimeout(_)) if Instant::now() < deadline => continue,
                Err(e) => return Err(e),
            }
        }
    }
}

fn accept_loop(listener: TcpListener, inner: Arc<ServerInner>) {
    loop {
        match listener.accept() {
            Ok((stream, _peer)) => {
                if inner.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                let conn_inner = inner.clone();
                let _ = std::thread::Builder::new()
                    .name("reverb-conn".into())
                    .spawn(move || {
                        let _ = serve_connection(stream, conn_inner);
                    });
            }
            Err(_) => {
                if inner.shutdown.load(Ordering::SeqCst) {
                    return;
                }
            }
        }
    }
}

/// Build a table `Item` from its wire form, resolving chunk references from
/// the per-connection pending set or the global store.
fn resolve_item(
    inner: &ServerInner,
    pending: &HashMap<u64, Arc<Chunk>>,
    wire: &WireItem,
) -> Result<Item> {
    let chunks = wire
        .chunk_keys
        .iter()
        .map(|k| {
            pending
                .get(k)
                .cloned()
                .map(Ok)
                .unwrap_or_else(|| inner.store.get(*k))
        })
        .collect::<Result<Vec<_>>>()?;
    Item::new(
        wire.key,
        wire.table.clone(),
        wire.priority,
        chunks,
        wire.offset as usize,
        wire.length as usize,
    )
}

/// Convert a sampled item to its wire form plus its chunk set.
fn sampled_to_wire(s: &crate::core::item::SampledItem) -> (WireSampleInfo, Vec<Arc<Chunk>>) {
    let info = WireSampleInfo {
        item: WireItem {
            key: s.item.key,
            table: s.item.table.clone(),
            priority: s.item.priority,
            chunk_keys: s.item.chunks.iter().map(|c| c.key).collect(),
            offset: s.item.offset as u64,
            length: s.item.length as u64,
            times_sampled: s.item.times_sampled,
        },
        probability: s.probability,
        table_size: s.table_size as u64,
    };
    (info, s.item.chunks.clone())
}

fn serve_connection(stream: TcpStream, inner: Arc<ServerInner>) -> Result<()> {
    stream.set_nodelay(true)?;
    let mut reader = BufReader::with_capacity(256 * 1024, stream.try_clone()?);
    let mut writer = BufWriter::with_capacity(256 * 1024, stream);
    // Chunks streamed on this connection, awaiting item creation.
    let mut pending: HashMap<u64, Arc<Chunk>> = HashMap::new();
    let mut pending_order: std::collections::VecDeque<u64> = std::collections::VecDeque::new();

    loop {
        if inner.shutdown.load(Ordering::SeqCst) {
            return Ok(());
        }
        let msg = match Message::read_frame(&mut reader) {
            Ok(m) => m,
            Err(Error::Io(_)) => return Ok(()), // client hung up
            Err(e) => return Err(e),
        };
        match msg {
            Message::InsertChunks { chunks } => {
                for chunk in chunks {
                    let key = chunk.key;
                    let arc = inner.store.insert(chunk);
                    if pending.insert(key, arc).is_none() {
                        pending_order.push_back(key);
                    }
                    while pending_order.len() > PENDING_CHUNK_CAP {
                        if let Some(old) = pending_order.pop_front() {
                            pending.remove(&old);
                        }
                    }
                }
                // No reply: chunk streaming is fire-and-forget, acks ride
                // on the subsequent CreateItem.
            }
            Message::CreateItem { id, item, timeout_ms } => {
                let reply = (|| {
                    let table = inner.table(&item.table)?.clone();
                    let item = resolve_item(&inner, &pending, &item)?;
                    inner.gated_insert(&table, item, Duration::from_millis(timeout_ms))?;
                    Ok(())
                })();
                send_reply(&mut writer, id, reply.map(|()| String::new()))?;
            }
            Message::SampleRequest {
                id,
                table,
                num_samples,
                timeout_ms,
            } => {
                let result = (|| {
                    let table = inner.table(&table)?.clone();
                    inner.gated_sample(
                        &table,
                        num_samples.max(1) as usize,
                        Duration::from_millis(timeout_ms),
                    )
                })();
                match result {
                    Ok(samples) => {
                        let mut infos = Vec::with_capacity(samples.len());
                        let mut chunks: Vec<Arc<Chunk>> = Vec::with_capacity(samples.len());
                        for s in &samples {
                            let (info, item_chunks) = sampled_to_wire(s);
                            infos.push(info);
                            for c in item_chunks {
                                // Dedup chunks shared across items in this
                                // response batch; encode straight from the
                                // Arc (no payload clone) — hot path. Linear
                                // scan beats a HashSet at batch sizes.
                                if !chunks.iter().any(|x| x.key == c.key) {
                                    chunks.push(c);
                                }
                            }
                        }
                        Message::write_sample_data_frame(&mut writer, id, &infos, &chunks)?;
                        writer.flush()?;
                    }
                    Err(e) => {
                        send_err(&mut writer, id, &e)?;
                    }
                }
            }
            Message::MutatePriorities {
                id,
                table,
                updates,
                deletes,
            } => {
                let reply = (|| {
                    let table = inner.table(&table)?.clone();
                    let _guard = inner.gate.enter();
                    let updated = table.update_priorities(&updates)?;
                    let deleted = table.delete(&deletes)?;
                    Ok(format!("updated={updated} deleted={deleted}"))
                })();
                send_reply(&mut writer, id, reply)?;
            }
            Message::Reset { id, table } => {
                let reply = (|| {
                    let table = inner.table(&table)?.clone();
                    let _guard = inner.gate.enter();
                    table.reset();
                    Ok(String::new())
                })();
                send_reply(&mut writer, id, reply)?;
            }
            Message::InfoRequest { id } => {
                let tables = inner
                    .table_order
                    .iter()
                    .map(|t| (t.name().to_string(), t.info()))
                    .collect();
                Message::Info { id, tables }.write_frame(&mut writer)?;
                writer.flush()?;
            }
            Message::Checkpoint { id } => {
                let reply = inner
                    .checkpoint()
                    .map(|p| p.display().to_string());
                send_reply(&mut writer, id, reply)?;
            }
            // Server-to-client messages arriving at the server are protocol
            // violations.
            Message::Ack { .. }
            | Message::Err { .. }
            | Message::SampleData { .. }
            | Message::Info { .. } => {
                return Err(Error::Decode("client sent a server-side message".into()));
            }
        }
    }
}

fn send_reply<W: Write>(w: &mut W, id: u64, result: Result<String>) -> Result<()> {
    match result {
        Ok(detail) => Message::Ack { id, detail }.write_frame(w)?,
        Err(e) => {
            Message::Err {
                id,
                code: error_code(&e),
                message: e.to_string(),
            }
            .write_frame(w)?;
        }
    }
    w.flush()?;
    Ok(())
}

fn send_err<W: Write>(w: &mut W, id: u64, e: &Error) -> Result<()> {
    Message::Err {
        id,
        code: error_code(e),
        message: e.to_string(),
    }
    .write_frame(w)?;
    w.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::chunk::Compression;
    use crate::core::tensor::Tensor;

    fn mk_chunk(key: u64, v: f32) -> Chunk {
        let steps = vec![vec![Tensor::from_f32(&[1], &[v]).unwrap()]];
        Chunk::from_steps(key, 0, &steps, Compression::None).unwrap()
    }

    fn start_server() -> Server {
        Server::builder()
            .table(TableConfig::uniform_replay("replay", 100))
            .table(TableConfig::queue("queue", 4))
            .bind("127.0.0.1:0")
            .unwrap()
    }

    /// Raw-protocol round trip (the typed Client is tested in client/).
    #[test]
    fn raw_insert_then_sample_over_tcp() {
        let server = start_server();
        let stream = TcpStream::connect(server.local_addr()).unwrap();
        stream.set_nodelay(true).unwrap();
        let mut r = BufReader::new(stream.try_clone().unwrap());
        let mut w = BufWriter::new(stream);

        Message::InsertChunks {
            chunks: vec![mk_chunk(11, 3.5)],
        }
        .write_frame(&mut w)
        .unwrap();
        Message::CreateItem {
            id: 1,
            item: WireItem {
                key: 7,
                table: "replay".into(),
                priority: 1.0,
                chunk_keys: vec![11],
                offset: 0,
                length: 1,
                times_sampled: 0,
            },
            timeout_ms: 1000,
        }
        .write_frame(&mut w)
        .unwrap();
        w.flush().unwrap();
        match Message::read_frame(&mut r).unwrap() {
            Message::Ack { id, .. } => assert_eq!(id, 1),
            other => panic!("expected ack, got {other:?}"),
        }

        Message::SampleRequest {
            id: 2,
            table: "replay".into(),
            num_samples: 1,
            timeout_ms: 1000,
        }
        .write_frame(&mut w)
        .unwrap();
        w.flush().unwrap();
        match Message::read_frame(&mut r).unwrap() {
            Message::SampleData { id, infos, chunks } => {
                assert_eq!(id, 2);
                assert_eq!(infos[0].item.key, 7);
                assert_eq!(chunks[0].key, 11);
                let steps = chunks[0].to_steps().unwrap();
                assert_eq!(steps[0][0].to_f32().unwrap(), vec![3.5]);
            }
            other => panic!("expected samples, got {other:?}"),
        }
    }

    #[test]
    fn unknown_table_errors() {
        let server = start_server();
        let stream = TcpStream::connect(server.local_addr()).unwrap();
        let mut r = BufReader::new(stream.try_clone().unwrap());
        let mut w = BufWriter::new(stream);
        Message::SampleRequest {
            id: 1,
            table: "nope".into(),
            num_samples: 1,
            timeout_ms: 10,
        }
        .write_frame(&mut w)
        .unwrap();
        w.flush().unwrap();
        match Message::read_frame(&mut r).unwrap() {
            Message::Err { code, .. } => assert_eq!(code, crate::net::wire::code::NOT_FOUND),
            other => panic!("expected err, got {other:?}"),
        }
    }

    #[test]
    fn sample_timeout_maps_to_timeout_code() {
        let server = start_server();
        let stream = TcpStream::connect(server.local_addr()).unwrap();
        let mut r = BufReader::new(stream.try_clone().unwrap());
        let mut w = BufWriter::new(stream);
        Message::SampleRequest {
            id: 1,
            table: "replay".into(),
            num_samples: 1,
            timeout_ms: 30,
        }
        .write_frame(&mut w)
        .unwrap();
        w.flush().unwrap();
        match Message::read_frame(&mut r).unwrap() {
            Message::Err { code, .. } => assert_eq!(code, crate::net::wire::code::TIMEOUT),
            other => panic!("expected timeout, got {other:?}"),
        }
    }

    #[test]
    fn info_request_reports_tables() {
        let server = start_server();
        let stream = TcpStream::connect(server.local_addr()).unwrap();
        let mut r = BufReader::new(stream.try_clone().unwrap());
        let mut w = BufWriter::new(stream);
        Message::InfoRequest { id: 5 }.write_frame(&mut w).unwrap();
        w.flush().unwrap();
        match Message::read_frame(&mut r).unwrap() {
            Message::Info { tables, .. } => {
                let names: Vec<&str> = tables.iter().map(|(n, _)| n.as_str()).collect();
                assert_eq!(names, vec!["replay", "queue"]);
            }
            other => panic!("expected info, got {other:?}"),
        }
    }

    #[test]
    fn stop_releases_blocked_clients() {
        let mut server = start_server();
        let addr = server.local_addr();
        let h = std::thread::spawn(move || {
            let stream = TcpStream::connect(addr).unwrap();
            let mut r = BufReader::new(stream.try_clone().unwrap());
            let mut w = BufWriter::new(stream);
            Message::SampleRequest {
                id: 1,
                table: "replay".into(),
                num_samples: 1,
                timeout_ms: 60_000,
            }
            .write_frame(&mut w)
            .unwrap();
            w.flush().unwrap();
            Message::read_frame(&mut r)
        });
        std::thread::sleep(Duration::from_millis(50));
        server.stop();
        match h.join().unwrap() {
            Ok(Message::Err { code, .. }) => {
                assert_eq!(code, crate::net::wire::code::CANCELLED)
            }
            Ok(other) => panic!("unexpected {other:?}"),
            // Connection torn down before the reply is also acceptable.
            Err(Error::Io(_)) => {}
            Err(e) => panic!("unexpected error {e}"),
        }
    }

    #[test]
    fn periodic_checkpointing_writes_files() {
        let dir = std::env::temp_dir().join(format!("reverb_periodic_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let server = Server::builder()
            .table(TableConfig::uniform_replay("t", 10))
            .checkpoint_dir(&dir)
            .checkpoint_interval(Duration::from_millis(60))
            .bind("127.0.0.1:0")
            .unwrap();
        // Write something so the checkpoints have content.
        let table = server.table("t").unwrap();
        let steps = vec![vec![crate::core::tensor::Tensor::from_f32(&[1], &[1.0]).unwrap()]];
        let chunk = std::sync::Arc::new(
            Chunk::from_steps(1, 0, &steps, Compression::None).unwrap(),
        );
        table
            .insert_or_assign(
                crate::core::item::Item::new(1, "t", 1.0, vec![chunk], 0, 1).unwrap(),
                None,
            )
            .unwrap();
        std::thread::sleep(Duration::from_millis(250));
        drop(server);
        let ckpts: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.path().extension().is_some_and(|x| x == "rvb"))
            .collect();
        assert!(ckpts.len() >= 2, "expected periodic checkpoints, got {}", ckpts.len());
        // And the newest one restores.
        let newest = ckpts.iter().map(|e| e.path()).max().unwrap();
        let restored = Server::builder()
            .table(TableConfig::uniform_replay("t", 10))
            .load_checkpoint(newest)
            .bind("127.0.0.1:0")
            .unwrap();
        assert_eq!(restored.table("t").unwrap().size(), 1);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn duplicate_table_rejected() {
        let r = Server::builder()
            .table(TableConfig::uniform_replay("t", 10))
            .table(TableConfig::uniform_replay("t", 10))
            .bind("127.0.0.1:0");
        assert!(r.is_err());
    }
}
