//! The Reverb server: tables exposed over the wire protocol through any
//! number of [`TransportListener`]s.
//!
//! Two service models exist (DESIGN.md §11):
//!
//! - **Event** (the default): `N = service_threads` workers drive
//!   per-connection state machines over a readiness poller
//!   (`net::event`), so connection count and CPU usage are decoupled —
//!   the paper's "thousands of concurrent clients" regime.
//! - **Threaded** (`--service-model threaded`): the original
//!   thread-per-connection model, kept for one release as a
//!   differential-testing oracle.
//!
//! Every server registers an in-process endpoint (`reverb://in-proc/...`);
//! [`ServerBuilder::bind`] additionally opens a TCP listener,
//! [`ServerBuilder::unix_socket`] a Unix-domain-socket listener, and
//! [`ServerBuilder::serve_in_proc`] serves the in-process path alone.

use crate::core::chunk::Chunk;
use crate::core::chunk_store::{ChunkHandle, ChunkStore};
use crate::core::extensions::TableExtension;
use crate::core::item::{Item, SampledItem};
use crate::core::table::{Table, TableConfig, TableInfo};
use crate::error::{Error, Result};
use crate::net::event::{default_service_threads, EventCore, EventShared};
use crate::net::gate::Gate;
use crate::net::transport::{
    self, InProcListener, MsgStream, TcpTransportListener, TransportListener,
};
use crate::net::metrics::{LatencyHistogram, TableLatency};
use crate::net::trace::{self, ReqSpans, Stage, TraceContext, SERVER_STAGES};
use crate::net::wire::{
    error_code, BatchResult, Message, WireItem, WireSampleInfo, MAX_BATCH_OPS,
};
use crate::persist::{PersistConfig, Persister, DEFAULT_SEGMENT_BYTES};
use std::collections::HashMap;
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// How connections are serviced (DESIGN.md §11).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServiceModel {
    /// One OS thread per connection — the legacy model, kept as a
    /// differential-testing oracle (`--service-model threaded`).
    Threaded,
    /// A fixed worker pool drives per-connection state machines over a
    /// readiness poller; blocked table ops suspend the connection, not a
    /// worker.
    Event,
}

/// How the server persists checkpoints (§3.7 / DESIGN.md §10).
#[derive(Clone, Debug)]
pub enum PersistMode {
    /// Stop-the-world full snapshot per checkpoint — the paper's §3.7
    /// semantics; the gate pause scales with table size.
    Full,
    /// Base snapshot + delta journal + background writer: the checkpoint
    /// gate pause is a constant-time journal rotation, and fsync happens
    /// off the request path.
    Incremental {
        /// Seal journal segments at about this many bytes.
        journal_segment_bytes: usize,
    },
}

impl PersistMode {
    /// Incremental persistence with the default segment size.
    pub fn incremental() -> Self {
        PersistMode::Incremental {
            journal_segment_bytes: DEFAULT_SEGMENT_BYTES,
        }
    }
}

/// Long blocking waits are sliced into segments of this length so the
/// checkpoint gate can drain promptly (see `net::gate`).
const WAIT_SLICE: Duration = Duration::from_millis(50);

/// Per-connection cache of recently streamed chunks awaiting item creation.
/// Bounded; writers create items promptly after streaming chunks.
pub(crate) const PENDING_CHUNK_CAP: usize = 1024;

/// Server construction options.
pub struct ServerBuilder {
    tables: Vec<(TableConfig, Vec<Box<dyn TableExtension>>)>,
    checkpoint_dir: Option<PathBuf>,
    load_checkpoint: Option<PathBuf>,
    checkpoint_interval: Option<Duration>,
    persist_mode: PersistMode,
    in_proc_name: Option<String>,
    service_model: ServiceModel,
    service_threads: Option<usize>,
    uds_path: Option<PathBuf>,
    metrics_addr: Option<String>,
    metrics_token: Option<String>,
    chunk_hot_bytes: Option<u64>,
    chunk_cold_dir: Option<PathBuf>,
}

impl ServerBuilder {
    pub fn new() -> Self {
        ServerBuilder {
            tables: Vec::new(),
            checkpoint_dir: None,
            load_checkpoint: None,
            checkpoint_interval: None,
            persist_mode: PersistMode::Full,
            in_proc_name: None,
            // The poller has no readiness source for socket fds off unix
            // (RawSock::raw_fd returns -1 there), so non-unix platforms
            // keep the thread-per-connection default.
            service_model: if cfg!(unix) {
                ServiceModel::Event
            } else {
                ServiceModel::Threaded
            },
            service_threads: None,
            uds_path: None,
            metrics_addr: None,
            metrics_token: None,
            chunk_hot_bytes: None,
            chunk_cold_dir: None,
        }
    }

    /// Cap the chunk store's in-memory (hot) tier at about `bytes` of
    /// encoded chunk payload. Chunks past the budget demote — least
    /// recently sampled first — to CRC-framed spill files under the
    /// directory set by [`ServerBuilder::chunk_cold_dir`], and rehydrate
    /// transparently when sampled again. Requires `chunk_cold_dir`.
    pub fn chunk_hot_bytes(mut self, bytes: u64) -> Self {
        self.chunk_hot_bytes = Some(bytes);
        self
    }

    /// Directory for the chunk store's cold-tier spill files. The files
    /// are an ephemeral cache (recreated from the tables' durable state
    /// on restart), so a fast local disk is ideal.
    pub fn chunk_cold_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.chunk_cold_dir = Some(dir.into());
        self
    }

    /// Additionally serve a plain-HTTP Prometheus `/metrics` endpoint on
    /// `addr` (use port 0 for an ephemeral port; see
    /// [`Server::metrics_addr`]). Under the event model each scrape socket
    /// is just another readiness source on the worker pool; the threaded
    /// model serves scrapes from short-lived threads.
    pub fn metrics_addr(mut self, addr: impl Into<String>) -> Self {
        self.metrics_addr = Some(addr.into());
        self
    }

    /// Require `Authorization: Bearer <token>` on every `/metrics` scrape
    /// (and any other HTTP request). The loopback default needs none, but
    /// a fabric member scraped across hosts does (DESIGN.md §14);
    /// unauthenticated requests get `401` before any path routing.
    pub fn metrics_token(mut self, token: impl Into<String>) -> Self {
        self.metrics_token = Some(token.into());
        self
    }

    /// Select how connections are serviced (default:
    /// [`ServiceModel::Event`]). [`ServiceModel::Threaded`] restores the
    /// legacy thread-per-connection behaviour.
    pub fn service_model(mut self, model: ServiceModel) -> Self {
        self.service_model = model;
        self
    }

    /// Size of the event-model worker pool (default: one per core).
    /// Ignored under [`ServiceModel::Threaded`].
    pub fn service_threads(mut self, n: usize) -> Self {
        self.service_threads = Some(n.max(1));
        self
    }

    /// Additionally serve a Unix-domain-socket listener at `path`
    /// (`reverb+unix:///path`). The socket file is removed at shutdown.
    pub fn unix_socket(mut self, path: impl Into<PathBuf>) -> Self {
        self.uds_path = Some(path.into());
        self
    }

    /// Add a table.
    pub fn table(mut self, config: TableConfig) -> Self {
        self.tables.push((config, Vec::new()));
        self
    }

    /// Add a table with extensions (§3.5).
    pub fn table_with_extensions(
        mut self,
        config: TableConfig,
        extensions: Vec<Box<dyn TableExtension>>,
    ) -> Self {
        self.tables.push((config, extensions));
        self
    }

    /// Directory for client-triggered checkpoints (§3.7).
    pub fn checkpoint_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.checkpoint_dir = Some(dir.into());
        self
    }

    /// Load this checkpoint at construction time (§3.7).
    pub fn load_checkpoint(mut self, path: impl Into<PathBuf>) -> Self {
        self.load_checkpoint = Some(path.into());
        self
    }

    /// Write a checkpoint automatically every `interval` (§3.7: "potential
    /// data loss ... can be limited through the use of periodic
    /// checkpointing"). Requires [`ServerBuilder::checkpoint_dir`]. Under
    /// [`PersistMode::Incremental`] each tick is a journal rotation +
    /// manifest commit, so short intervals stay cheap.
    pub fn checkpoint_interval(mut self, interval: Duration) -> Self {
        self.checkpoint_interval = Some(interval);
        self
    }

    /// Select the checkpoint persistence mode (default:
    /// [`PersistMode::Full`], the seed's stop-the-world behaviour).
    /// [`PersistMode::Incremental`] requires
    /// [`ServerBuilder::checkpoint_dir`]; if that directory already holds
    /// a manifest from a previous incarnation and no explicit
    /// [`ServerBuilder::load_checkpoint`] was given, the server restores
    /// it automatically before serving (a plain restart never wipes the
    /// durable chain).
    pub fn persist_mode(mut self, mode: PersistMode) -> Self {
        self.persist_mode = mode;
        self
    }

    /// Name the in-process endpoint (default: a process-unique name).
    pub fn in_proc_name(mut self, name: impl Into<String>) -> Self {
        self.in_proc_name = Some(name.into());
        self
    }

    /// Bind a TCP listener on `addr` (use port 0 for an ephemeral port) and
    /// start serving. The in-process endpoint is registered as well.
    pub fn bind(self, addr: &str) -> Result<Server> {
        let tcp = TcpTransportListener::bind(addr)?;
        let local_addr = tcp.local_addr();
        let in_proc_name = self.in_proc_name.clone();
        let in_proc = InProcListener::bind(in_proc_name)?;
        self.start(Some((tcp, local_addr)), in_proc)
    }

    /// Serve the zero-copy in-process transport only — no sockets at all.
    /// Clients connect via [`Server::in_proc_addr`].
    pub fn serve_in_proc(self) -> Result<Server> {
        let in_proc = InProcListener::bind(self.in_proc_name.clone())?;
        self.start(None, in_proc)
    }

    fn start(
        self,
        tcp: Option<(TcpTransportListener, SocketAddr)>,
        in_proc: InProcListener,
    ) -> Result<Server> {
        let mut tables = HashMap::new();
        let mut table_order = Vec::new();
        for (config, extensions) in self.tables {
            let name = config.name.clone();
            let t = Arc::new(Table::with_extensions(config, extensions));
            table_order.push(t.clone());
            if tables.insert(name.clone(), t).is_some() {
                // `in_proc` unbinds itself on drop (token-guarded RAII).
                return Err(Error::InvalidArgument(format!("duplicate table {name}")));
            }
        }
        // Align chunk-store lock granularity with the most-sharded table so
        // InsertChunks never contends on coarser locks than CreateItem.
        let store_shards = table_order
            .iter()
            .map(|t| t.num_shards())
            .max()
            .unwrap_or(1)
            .max(crate::core::chunk_store::DEFAULT_NUM_SHARDS);
        let store = match (self.chunk_hot_bytes, &self.chunk_cold_dir) {
            (Some(hot_bytes), Some(dir)) => ChunkStore::with_tiering(
                store_shards,
                crate::core::chunk_store::TieringConfig::new(hot_bytes, dir.clone()),
            )?,
            (Some(_), None) => {
                return Err(Error::InvalidArgument(
                    "chunk_hot_bytes requires chunk_cold_dir".into(),
                ));
            }
            (None, _) => ChunkStore::with_shards(store_shards),
        };
        if let Some(path) = &self.load_checkpoint {
            crate::core::checkpoint::load(path, &table_order, &store)?;
        } else if matches!(self.persist_mode, PersistMode::Incremental { .. }) {
            // Starting the persister rewrites the manifest and garbage-
            // collects the old chain, so an incremental server that finds
            // an existing manifest in its checkpoint_dir MUST restore it
            // first — otherwise a plain restart (no --load) would wipe the
            // very state this subsystem exists to protect.
            if let Some(dir) = &self.checkpoint_dir {
                let manifest = dir.join(crate::persist::MANIFEST_NAME);
                if manifest.exists() {
                    crate::core::checkpoint::load(&manifest, &table_order, &store)?;
                }
            }
        }
        // Incremental persistence attaches after any restore: the journal
        // starts from the fresh base the persister writes at startup.
        let persister = match (&self.persist_mode, &self.checkpoint_dir) {
            (PersistMode::Incremental { journal_segment_bytes }, Some(dir)) => Some(
                Persister::start(
                    PersistConfig::new(dir.clone()).with_segment_bytes(*journal_segment_bytes),
                    &table_order,
                )?,
            ),
            (PersistMode::Incremental { .. }, None) => {
                return Err(Error::InvalidArgument(
                    "incremental persistence requires checkpoint_dir".into(),
                ));
            }
            (PersistMode::Full, _) => None,
        };
        // One service-time histogram pair per table, fed from the dispatch
        // paths of both service models and rendered at `/metrics`.
        let latency = tables
            .keys()
            .map(|name| (name.clone(), TableLatency::default()))
            .collect();
        // Stage histograms: one row per table plus the `_server`
        // pseudo-table for connection-scoped stages.
        let stages = tables
            .keys()
            .cloned()
            .chain(std::iter::once("_server".to_string()))
            .map(|name| {
                (
                    name,
                    std::array::from_fn(|_| LatencyHistogram::default()),
                )
            })
            .collect();
        let inner = Arc::new(ServerInner {
            tables,
            table_order,
            latency,
            stages,
            store,
            gate: Gate::new(),
            checkpoint_dir: self.checkpoint_dir,
            checkpoint_seq: AtomicU64::new(0),
            persister,
            checkpoint_interval_ms: AtomicU64::new(
                self.checkpoint_interval
                    .map(|i| (i.as_millis() as u64).max(1))
                    .unwrap_or(0),
            ),
            metrics_token: self.metrics_token,
            shutdown: AtomicBool::new(false),
        });

        let in_proc_addr = in_proc.endpoint();
        let in_proc_name = in_proc.name().to_string();
        let mut shutdowns = vec![ListenerShutdown::InProc(in_proc_name)];
        let mut listeners: Vec<Box<dyn TransportListener>> = vec![Box::new(in_proc)];
        let local_addr = tcp.map(|(listener, addr)| {
            shutdowns.push(ListenerShutdown::Tcp(addr));
            listeners.push(Box::new(listener));
            addr
        });
        let uds_addr = match &self.uds_path {
            Some(path) => {
                #[cfg(unix)]
                {
                    let listener = transport::UnixTransportListener::bind(path)?;
                    let addr = listener.endpoint();
                    shutdowns.push(ListenerShutdown::Unix(path.clone()));
                    listeners.push(Box::new(listener));
                    Some(addr)
                }
                #[cfg(not(unix))]
                {
                    let _ = path;
                    return Err(Error::InvalidArgument(
                        "unix-domain sockets are not supported on this platform".into(),
                    ));
                }
            }
            None => None,
        };

        // The event-driven service core (DESIGN.md §11), unless the
        // threaded differential oracle was requested.
        let event = match self.service_model {
            ServiceModel::Event => Some(EventCore::start(
                inner.clone(),
                self.service_threads.unwrap_or_else(default_service_threads),
            )?),
            ServiceModel::Threaded => None,
        };
        let driver = match &event {
            Some(core) => ServiceDriver::Event(core.shared()),
            None => ServiceDriver::Threaded,
        };

        let mut accept_threads = Vec::with_capacity(listeners.len());
        for listener in listeners {
            let accept_inner = inner.clone();
            let accept_driver = driver.clone();
            accept_threads.push(
                std::thread::Builder::new()
                    .name("reverb-accept".into())
                    .spawn(move || accept_loop(listener, accept_inner, accept_driver))
                    .expect("spawn accept thread"),
            );
        }

        // Periodic checkpointer (§3.7), if configured. It parks on a
        // condvar signalled by `stop()`, so shutdown latency is bounded by
        // an in-flight checkpoint, never by the interval — and it re-reads
        // the interval each tick, so an admin re-tune takes effect at the
        // next park.
        let stop_signal = Arc::new(StopSignal::default());
        let checkpoint_thread = self.checkpoint_interval.map(|_| {
            if inner.checkpoint_dir.is_none() {
                panic!("checkpoint_interval requires checkpoint_dir");
            }
            let ckpt_inner = inner.clone();
            let signal = stop_signal.clone();
            std::thread::Builder::new()
                .name("reverb-ckpt".into())
                .spawn(move || loop {
                    let interval = Duration::from_millis(
                        ckpt_inner.checkpoint_interval_ms.load(Ordering::SeqCst).max(1),
                    );
                    if signal.wait_stop(interval) {
                        return;
                    }
                    if let Err(e) = ckpt_inner.checkpoint() {
                        log::warn!("periodic checkpoint failed: {e}");
                    }
                })
                .expect("spawn checkpoint thread")
        });

        // The `/metrics` exporter, if requested: a plain-HTTP listener
        // whose scrape sockets are fed to the event core as readiness
        // sources (or to short-lived threads under the threaded model).
        let metrics_local = match &self.metrics_addr {
            Some(addr) => {
                let listener = std::net::TcpListener::bind(addr.as_str())?;
                let local = listener.local_addr()?;
                shutdowns.push(ListenerShutdown::Tcp(local));
                let m_inner = inner.clone();
                let m_event = event.as_ref().map(|c| c.shared());
                accept_threads.push(
                    std::thread::Builder::new()
                        .name("reverb-metrics".into())
                        .spawn(move || metrics_accept_loop(listener, m_inner, m_event))
                        .expect("spawn metrics accept thread"),
                );
                Some(local)
            }
            None => None,
        };

        Ok(Server {
            inner,
            local_addr,
            in_proc_addr,
            uds_addr,
            metrics_local,
            shutdowns,
            accept_threads,
            checkpoint_thread,
            stop_signal,
            event,
        })
    }
}

/// Shutdown handshake for the periodic checkpoint thread: `wait_stop`
/// parks for one interval or until `signal()` fires, whichever is first.
#[derive(Default)]
struct StopSignal {
    stopped: Mutex<bool>,
    cv: Condvar,
}

impl StopSignal {
    /// Returns `true` when stop was signalled (possibly before the full
    /// interval elapsed).
    fn wait_stop(&self, interval: Duration) -> bool {
        let deadline = Instant::now() + interval;
        let mut stopped = self.stopped.lock().unwrap();
        loop {
            if *stopped {
                return true;
            }
            let now = Instant::now();
            if now >= deadline {
                return false;
            }
            let (guard, _timeout) = self.cv.wait_timeout(stopped, deadline - now).unwrap();
            stopped = guard;
        }
    }

    fn signal(&self) {
        *self.stopped.lock().unwrap() = true;
        self.cv.notify_all();
    }
}

/// How accepted connections are handed to the service layer.
#[derive(Clone)]
enum ServiceDriver {
    Threaded,
    Event(Arc<EventShared>),
}

impl Default for ServerBuilder {
    fn default() -> Self {
        Self::new()
    }
}

pub(crate) struct ServerInner {
    tables: HashMap<String, Arc<Table>>,
    /// Construction order (stable info/checkpoint ordering).
    pub(crate) table_order: Vec<Arc<Table>>,
    /// Per-table insert/sample service-time histograms (`/metrics`).
    pub(crate) latency: HashMap<String, TableLatency>,
    /// Per-table per-stage duration histograms (DESIGN.md §15), keyed by
    /// table name plus the `_server` pseudo-table for connection-scoped
    /// stages (decode/queue/flush) and ops with no table attribution.
    pub(crate) stages: HashMap<String, [LatencyHistogram; SERVER_STAGES.len()]>,
    pub(crate) store: ChunkStore,
    pub(crate) gate: Gate,
    checkpoint_dir: Option<PathBuf>,
    checkpoint_seq: AtomicU64,
    /// Incremental persistence (DESIGN.md §10); `None` = legacy full
    /// snapshots.
    persister: Option<Arc<Persister>>,
    /// Live periodic-checkpoint interval in milliseconds; 0 = periodic
    /// checkpointing not configured (no checkpoint thread exists, so the
    /// admin RPC rejects attempts to set it). The checkpoint thread
    /// re-reads this every tick, so a re-tune never needs a restart.
    pub(crate) checkpoint_interval_ms: AtomicU64,
    /// Optional bearer token required on `/metrics` scrapes (DESIGN.md
    /// §14); `None` = unauthenticated (loopback default).
    pub(crate) metrics_token: Option<String>,
    shutdown: AtomicBool,
}

/// How to unblock one listener's accept loop on shutdown.
enum ListenerShutdown {
    /// Dummy-connect to wake the blocking `accept`.
    Tcp(SocketAddr),
    /// Unbind the registry entry; the accept channel disconnects.
    InProc(String),
    /// Dummy-connect the socket path to wake the blocking `accept`.
    #[cfg_attr(not(unix), allow(dead_code))]
    Unix(PathBuf),
}

/// A running Reverb server. Dropping (or calling [`Server::stop`]) shuts it
/// down and releases all blocked clients.
pub struct Server {
    inner: Arc<ServerInner>,
    local_addr: Option<SocketAddr>,
    in_proc_addr: String,
    uds_addr: Option<String>,
    metrics_local: Option<SocketAddr>,
    shutdowns: Vec<ListenerShutdown>,
    accept_threads: Vec<std::thread::JoinHandle<()>>,
    checkpoint_thread: Option<std::thread::JoinHandle<()>>,
    stop_signal: Arc<StopSignal>,
    /// The event-driven service core; `None` under
    /// [`ServiceModel::Threaded`].
    event: Option<EventCore>,
}

impl Server {
    /// Convenience: builder.
    pub fn builder() -> ServerBuilder {
        ServerBuilder::new()
    }

    /// The bound TCP address (e.g. `127.0.0.1:41523`).
    ///
    /// Panics for in-process-only servers ([`ServerBuilder::serve_in_proc`]);
    /// use [`Server::tcp_addr`] to probe.
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
            .expect("server has no TCP listener (in-proc only)")
    }

    /// The bound TCP address, if a TCP listener was requested.
    pub fn tcp_addr(&self) -> Option<SocketAddr> {
        self.local_addr
    }

    /// The in-process endpoint (`reverb://in-proc/<name>`), always
    /// available. Same-process clients connecting here skip
    /// serialization and syscalls entirely.
    pub fn in_proc_addr(&self) -> String {
        self.in_proc_addr.clone()
    }

    /// The Unix-domain-socket endpoint (`reverb+unix:///path`), if one was
    /// requested via [`ServerBuilder::unix_socket`].
    pub fn uds_addr(&self) -> Option<String> {
        self.uds_addr.clone()
    }

    /// The bound `/metrics` HTTP address, if an exporter was requested via
    /// [`ServerBuilder::metrics_addr`].
    pub fn metrics_addr(&self) -> Option<SocketAddr> {
        self.metrics_local
    }

    /// Live connections currently tracked by the event-driven core
    /// (`None` under [`ServiceModel::Threaded`], which does not track its
    /// connection threads).
    pub fn live_connections(&self) -> Option<usize> {
        self.event.as_ref().map(|e| e.shared().live_conns())
    }

    /// Direct in-process access to a table — used by benchmarks that want
    /// to isolate table behaviour from transport cost, and by embedded
    /// (single-process) deployments.
    pub fn table(&self, name: &str) -> Result<Arc<Table>> {
        self.inner
            .tables
            .get(name)
            .cloned()
            .ok_or_else(|| Error::TableNotFound(name.into()))
    }

    /// The server's chunk store — tier statistics for tests/diagnostics,
    /// and [`ChunkStore::run_maintenance`] for deterministic demotion in
    /// tests.
    pub fn chunk_store(&self) -> &ChunkStore {
        &self.inner.store
    }

    /// Info for all tables, in construction order.
    pub fn info(&self) -> Vec<(String, TableInfo)> {
        self.inner
            .table_order
            .iter()
            .map(|t| (t.name().to_string(), t.info()))
            .collect()
    }

    /// Write a checkpoint now (also reachable via the client RPC). Under
    /// [`PersistMode::Incremental`] the returned path is the manifest.
    pub fn checkpoint(&self) -> Result<PathBuf> {
        self.inner.checkpoint()
    }

    /// Duration requests were blocked by the most recent checkpoint's
    /// §3.7 gate pause — constant under [`PersistMode::Incremental`],
    /// table-size-proportional under [`PersistMode::Full`]
    /// (`benches/checkpoint_pause.rs`).
    pub fn last_checkpoint_pause(&self) -> Duration {
        self.inner.gate.last_pause()
    }

    /// Stop serving: wake blocked clients, close the listeners, join.
    pub fn stop(&mut self) {
        if self.inner.shutdown.swap(true, Ordering::SeqCst) {
            return;
        }
        // Cancelling tables wakes blocked handlers (threaded model) and
        // fires the re-arm hooks of parked connections (event model), so
        // their Cancelled error replies are produced before the worker
        // pool drains and exits below.
        for t in &self.inner.table_order {
            t.cancel();
        }
        // Unpark the checkpoint thread immediately — stop latency must not
        // scale with --checkpoint-interval.
        self.stop_signal.signal();
        for s in &self.shutdowns {
            match s {
                // Unblock the accept loop.
                ListenerShutdown::Tcp(addr) => {
                    let _ = TcpStream::connect(addr);
                }
                ListenerShutdown::InProc(name) => transport::in_proc_unbind(name),
                ListenerShutdown::Unix(_path) => {
                    #[cfg(unix)]
                    {
                        let _ = std::os::unix::net::UnixStream::connect(_path);
                    }
                }
            }
        }
        for h in self.accept_threads.drain(..) {
            let _ = h.join();
        }
        if let Some(h) = self.checkpoint_thread.take() {
            let _ = h.join();
        }
        if let Some(event) = &mut self.event {
            event.stop();
        }
        // Final journal rotation + durable manifest, then join the
        // background writer.
        if let Some(p) = &self.inner.persister {
            p.stop(&self.inner.table_order);
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop();
    }
}

impl ServerInner {
    pub(crate) fn table(&self, name: &str) -> Result<&Arc<Table>> {
        self.tables
            .get(name)
            .ok_or_else(|| Error::TableNotFound(name.into()))
    }

    /// Record one insert op's service time (dispatch to reply) into the
    /// table's `/metrics` histogram. Unknown tables are skipped — there
    /// is no series to attribute the op to.
    pub(crate) fn record_insert_latency(&self, table: &str, started: Instant) {
        if let Some(tl) = self.latency.get(table) {
            tl.insert.record(started.elapsed());
        }
    }

    /// Record one sample op's service time (see
    /// [`ServerInner::record_insert_latency`]).
    pub(crate) fn record_sample_latency(&self, table: &str, started: Instant) {
        if let Some(tl) = self.latency.get(table) {
            tl.sample.record(started.elapsed());
        }
    }

    /// Record one stage duration into the per-table stage histogram
    /// (`reverb_stage_duration_seconds`). Unknown tables fall back to the
    /// `_server` pseudo-table so no stage time is ever dropped; client-only
    /// stages are ignored (they have no server histogram row).
    pub(crate) fn record_stage(&self, table: &str, stage: Stage, d: Duration) {
        let Some(idx) = stage.server_index() else {
            return;
        };
        if let Some(row) = self.stages.get(table).or_else(|| self.stages.get("_server")) {
            row[idx].record(d);
        }
    }

    /// Bytes sealed into the persist journal but not yet spilled to disk
    /// (0 without incremental persistence) — the `/metrics` lag gauge.
    pub(crate) fn journal_lag_bytes(&self) -> u64 {
        self.persister
            .as_ref()
            .map(|p| p.journal_lag_bytes())
            .unwrap_or(0)
    }

    /// Apply one admin reconfiguration (shared by both service models).
    /// Every request is validated in full before anything is applied, so a
    /// rejected reconfig leaves the server exactly as it was. Corridor
    /// bounds must be re-tuned as a pair (the limiter validates their
    /// width); `table` is ignored — and may be empty — for interval-only
    /// requests. Returns the audit line, which is both logged and sent
    /// back as the Ack detail.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn apply_admin(
        &self,
        table: &str,
        max_size: Option<u64>,
        min_diff: Option<f64>,
        max_diff: Option<f64>,
        checkpoint_interval_ms: Option<u64>,
        slow_request_micros: Option<u64>,
        trace_sample_per_mille: Option<u64>,
    ) -> Result<String> {
        if max_size.is_none()
            && min_diff.is_none()
            && max_diff.is_none()
            && checkpoint_interval_ms.is_none()
            && slow_request_micros.is_none()
            && trace_sample_per_mille.is_none()
        {
            return Err(Error::InvalidArgument(
                "empty reconfig: nothing to apply".into(),
            ));
        }
        if min_diff.is_some() != max_diff.is_some() {
            return Err(Error::InvalidArgument(
                "corridor re-tune requires both min_diff and max_diff".into(),
            ));
        }
        if let Some(ms) = checkpoint_interval_ms {
            if ms == 0 {
                return Err(Error::InvalidArgument(
                    "checkpoint interval must be positive".into(),
                ));
            }
            if self.checkpoint_interval_ms.load(Ordering::SeqCst) == 0 {
                return Err(Error::InvalidArgument(
                    "periodic checkpointing is not configured on this server".into(),
                ));
            }
        }
        if max_size == Some(0) {
            return Err(Error::InvalidArgument("max_size must be positive".into()));
        }
        if slow_request_micros == Some(0) {
            return Err(Error::InvalidArgument(
                "slow request threshold must be positive".into(),
            ));
        }
        if let Some(pm) = trace_sample_per_mille {
            if pm > 1000 {
                return Err(Error::InvalidArgument(format!(
                    "trace sampling rate {pm}\u{2030} exceeds 1000\u{2030}"
                )));
            }
        }
        let mut audit = Vec::new();
        if max_size.is_some() || min_diff.is_some() {
            let t = self.table(table)?;
            // The corridor is the last fallible apply (the limiter rejects
            // NaN and too-narrow spans); max_size cannot fail past the
            // zero pre-check above, so failure still leaves nothing
            // applied.
            if let (Some(lo), Some(hi)) = (min_diff, max_diff) {
                t.set_rate_limiter_corridor(lo, hi)?;
                audit.push(format!("corridor=[{lo}, {hi}]"));
            }
            if let Some(n) = max_size {
                t.set_max_size(n as usize)?;
                audit.push(format!("max_size={n}"));
            }
        }
        if let Some(ms) = checkpoint_interval_ms {
            self.checkpoint_interval_ms.store(ms, Ordering::SeqCst);
            audit.push(format!("checkpoint_interval_ms={ms}"));
        }
        if let Some(us) = slow_request_micros {
            trace::set_slow_request_micros(us);
            audit.push(format!("slow_request_micros={us}"));
        }
        if let Some(pm) = trace_sample_per_mille {
            trace::set_server_sample_per_mille(pm);
            audit.push(format!("trace_sample_per_mille={pm}"));
        }
        let detail = format!("reconfigured table={table:?} {}", audit.join(" "));
        log::info!("admin: {detail}");
        Ok(detail)
    }

    pub(crate) fn checkpoint(&self) -> Result<PathBuf> {
        if let Some(persister) = &self.persister {
            // Incremental (§3.7 revisited, DESIGN.md §10): the pause only
            // covers draining in-flight handlers plus a constant-time
            // journal rotation — independent of table size. Durability
            // (segment spill + manifest fsync) is awaited after the gate
            // has reopened, on the background writer.
            self.gate.pause();
            let pending = persister.rotate(&self.table_order);
            self.gate.resume();
            return pending.wait();
        }
        let dir = self
            .checkpoint_dir
            .clone()
            .ok_or_else(|| Error::InvalidArgument("server has no checkpoint_dir".into()))?;
        // Block all incoming requests for the duration (§3.7).
        self.gate.pause();
        let result = (|| {
            let seq = self.checkpoint_seq.fetch_add(1, Ordering::SeqCst);
            let path = dir.join(format!("ckpt_{seq:06}.rvb"));
            crate::core::checkpoint::save(&path, &self.table_order)?;
            Ok(path)
        })();
        self.gate.resume();
        result
    }

    /// Insert with gate-sliced blocking (see WAIT_SLICE). The item is
    /// cloned per attempt (cheap: `Arc<Chunk>` refs + metadata) so a sliced
    /// timeout can retry after re-entering the gate. Gate-pause waits and
    /// timed-out corridor slices accrue to the `gate` stage, matching the
    /// event model's attribution of parked time (DESIGN.md §15).
    fn gated_insert(
        &self,
        table: &Arc<Table>,
        item: Item,
        timeout: Duration,
        spans: &mut ReqSpans,
    ) -> Result<()> {
        let deadline = Instant::now() + timeout;
        loop {
            let (_guard, waited) = self.gate.enter_timed();
            spans.gate += waited;
            let now = Instant::now();
            let slice = WAIT_SLICE.min(deadline.saturating_duration_since(now));
            let attempt_started = Instant::now();
            match table.insert_or_assign(item.clone(), Some(slice)) {
                Ok(()) => {
                    spans.op_attempt(attempt_started.elapsed());
                    return Ok(());
                }
                Err(Error::RateLimiterTimeout(_)) if Instant::now() < deadline => {
                    accrue_blocked_slice(spans, attempt_started);
                    continue;
                }
                Err(e) => {
                    spans.op_attempt(attempt_started.elapsed());
                    return Err(e);
                }
            }
        }
    }

    /// Sample with gate-sliced blocking (stage attribution as in
    /// [`ServerInner::gated_insert`]).
    fn gated_sample(
        &self,
        table: &Arc<Table>,
        n: usize,
        timeout: Duration,
        spans: &mut ReqSpans,
    ) -> Result<Vec<crate::core::item::SampledItem>> {
        let deadline = Instant::now() + timeout;
        loop {
            let (_guard, waited) = self.gate.enter_timed();
            spans.gate += waited;
            let now = Instant::now();
            let slice = WAIT_SLICE.min(deadline.saturating_duration_since(now));
            let attempt_started = Instant::now();
            match table.sample_batch(n, Some(slice)) {
                Ok(items) => {
                    spans.op_attempt(attempt_started.elapsed());
                    return Ok(items);
                }
                Err(Error::RateLimiterTimeout(_)) if Instant::now() < deadline => {
                    accrue_blocked_slice(spans, attempt_started);
                    continue;
                }
                Err(e) => {
                    spans.op_attempt(attempt_started.elapsed());
                    return Err(e);
                }
            }
        }
    }
}

/// A timed-out WAIT_SLICE attempt spent its wall time corridor-blocked:
/// drain the TLS lock/journal accumulators into their stages and charge
/// the remainder to `gate` (not `execute` — no table op completed).
fn accrue_blocked_slice(spans: &mut ReqSpans, attempt_started: Instant) {
    let total = attempt_started.elapsed();
    let lock = trace::take_lock_wait();
    let journal = trace::take_journal_wait();
    spans.lock += lock;
    spans.journal += journal;
    spans.gate += total.saturating_sub(lock).saturating_sub(journal);
}

/// Feed a finished request's stage durations into the per-table `/metrics`
/// histograms (the threaded-model twin of `event::finish_spans`).
fn finish_spans(inner: &ServerInner, spans: ReqSpans, table: &str, started: Instant) {
    for (stage, d) in spans.finish(table, started) {
        if !d.is_zero() {
            inner.record_stage(table, stage, d);
        }
    }
}

/// Promote an untraced request to a server-sampled trace (flight-recorder
/// visibility without client cooperation; never echoed on replies).
fn server_trace() -> Option<TraceContext> {
    trace::should_sample_server().then(TraceContext::generate)
}

fn accept_loop(
    mut listener: Box<dyn TransportListener>,
    inner: Arc<ServerInner>,
    driver: ServiceDriver,
) {
    loop {
        match listener.accept() {
            Ok(Some(stream)) => {
                if inner.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                match &driver {
                    ServiceDriver::Threaded => {
                        let conn_inner = inner.clone();
                        let _ = std::thread::Builder::new()
                            .name("reverb-conn".into())
                            .spawn(move || {
                                let _ = serve_connection(stream, conn_inner);
                            });
                    }
                    ServiceDriver::Event(shared) => shared.add_conn(stream),
                }
            }
            // Listener closed cleanly (in-proc unbind).
            Ok(None) => return,
            Err(_) => {
                if inner.shutdown.load(Ordering::SeqCst) {
                    return;
                }
            }
        }
    }
}

/// Accept loop of the `/metrics` listener. Under the event model each
/// accepted scrape socket becomes another readiness source on the worker
/// pool; under the threaded model (or when fd polling is unavailable) a
/// short-lived thread serves the scrape — scrapes are rare and bounded, so
/// the thread cost is negligible there.
fn metrics_accept_loop(
    listener: std::net::TcpListener,
    inner: Arc<ServerInner>,
    event: Option<Arc<EventShared>>,
) {
    loop {
        match listener.accept() {
            Ok((sock, _peer)) => {
                if inner.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                let fallback = match &event {
                    Some(shared) => shared.add_http_conn(sock).err(),
                    None => Some(sock),
                };
                if let Some(sock) = fallback {
                    let scrape_inner = inner.clone();
                    let scrape_event = event.clone();
                    let _ = std::thread::Builder::new()
                        .name("reverb-scrape".into())
                        .spawn(move || {
                            let _ = serve_metrics_scrape(
                                sock,
                                &scrape_inner,
                                scrape_event.as_deref(),
                            );
                        });
                }
            }
            Err(_) => {
                if inner.shutdown.load(Ordering::SeqCst) {
                    return;
                }
            }
        }
    }
}

/// One blocking `/metrics` scrape (threaded fallback): read the request
/// head, reply with the Prometheus exposition (or 404), close. Replies are
/// `Connection: close`, so there is no keep-alive state to manage.
fn serve_metrics_scrape(
    mut sock: TcpStream,
    inner: &ServerInner,
    event: Option<&EventShared>,
) -> std::io::Result<()> {
    use std::io::Write;
    sock.set_read_timeout(Some(Duration::from_secs(5)))?;
    let Some(head) = crate::net::metrics::read_request_head(&mut sock)? else {
        return Ok(()); // oversized request: drop the connection
    };
    let response = crate::net::metrics::http_response(&head, inner, event);
    sock.write_all(&response)?;
    sock.flush()
}

/// Build a table `Item` from its wire form, resolving chunk references from
/// the per-connection pending set or the global store. Trajectory items
/// (v2 frames) are validated per column against the resolved chunks:
/// `Item::new_trajectory` rejects slices that overrun a chunk, reference a
/// chunk the item does not carry, or gather from multi-field chunks.
/// Stash freshly streamed chunks in the global store and the
/// per-connection pending set (bounded by [`PENDING_CHUNK_CAP`]). Shared
/// by both service models so their chunk-retention policies cannot drift.
pub(crate) fn stash_chunks(
    inner: &ServerInner,
    pending: &mut HashMap<u64, ChunkHandle>,
    pending_order: &mut std::collections::VecDeque<u64>,
    chunks: Vec<Arc<Chunk>>,
) {
    for chunk in chunks {
        let key = chunk.key;
        let arc = inner.store.insert_arc(chunk);
        if pending.insert(key, arc).is_none() {
            pending_order.push_back(key);
        }
        while pending_order.len() > PENDING_CHUNK_CAP {
            if let Some(old) = pending_order.pop_front() {
                pending.remove(&old);
            }
        }
    }
}

pub(crate) fn resolve_item(
    inner: &ServerInner,
    pending: &HashMap<u64, ChunkHandle>,
    wire: &WireItem,
) -> Result<Item> {
    let chunks = wire
        .chunk_keys
        .iter()
        .map(|k| {
            pending
                .get(k)
                .cloned()
                .map(Ok)
                .unwrap_or_else(|| inner.store.get(*k))
        })
        .collect::<Result<Vec<_>>>()?;
    match &wire.columns {
        Some(columns) => Item::new_trajectory_shared(
            wire.key,
            wire.table.clone(),
            wire.priority,
            chunks,
            columns.clone(),
        ),
        None => Item::new(
            wire.key,
            wire.table.clone(),
            wire.priority,
            chunks,
            wire.offset as usize,
            wire.length as usize,
        ),
    }
}

/// Convert a sampled item to its wire form plus its chunk set. Resolving
/// the item's handles is the sample path's rehydration point: cold-tier
/// chunks are read back (CRC-checked) and promoted hot here, so the wire
/// and in-proc transports always see fully materialized chunks.
fn sampled_to_wire(s: &SampledItem) -> Result<(WireSampleInfo, Vec<Arc<Chunk>>)> {
    let info = WireSampleInfo {
        item: WireItem {
            key: s.item.key,
            table: s.item.table.clone(),
            priority: s.item.priority,
            chunk_keys: s.item.chunks.iter().map(|c| c.key).collect(),
            offset: s.item.offset as u64,
            length: s.item.length as u64,
            times_sampled: s.item.times_sampled,
            columns: s.item.columns.clone(),
        },
        probability: s.probability,
        table_size: s.table_size as u64,
    };
    let chunks = s
        .item
        .chunks
        .iter()
        .map(|c| c.resolve())
        .collect::<Result<Vec<_>>>()?;
    Ok((info, chunks))
}

/// Build the `SampleData` response for a batch, deduplicating chunks
/// shared across items. The response carries the shared handles: TCP/UDS
/// encode straight from them, in-proc hands them to the client as-is — no
/// payload clone either way (hot path). Linear scan beats a HashSet at
/// batch sizes. Shared by both service models.
pub(crate) fn sample_reply(id: u64, samples: &[SampledItem]) -> Result<Message> {
    let mut infos = Vec::with_capacity(samples.len());
    let mut chunks: Vec<Arc<Chunk>> = Vec::with_capacity(samples.len());
    for s in samples {
        let (info, item_chunks) = sampled_to_wire(s)?;
        infos.push(info);
        for c in item_chunks {
            if !chunks.iter().any(|x| x.key == c.key) {
                chunks.push(c);
            }
        }
    }
    Ok(Message::SampleData { id, infos, chunks })
}

/// How often a threaded-model connection with live watch subscriptions
/// checks its dirty bit between frames (the event model needs no tick: its
/// watcher hooks schedule the connection directly).
const WATCH_TICK: Duration = Duration::from_millis(2);

/// Push one coalesced [`Message::WatchUpdate`] per subscription on this
/// connection if any watcher hook fired since the last push. Latest-wins
/// backpressure: however many mutations landed in the window, the
/// subscriber sees a single current snapshot per subscription (DESIGN.md
/// §12). Shared dirty bit per connection, so one firing refreshes every
/// subscription — subscribers key on the watch id.
fn flush_watch_updates(
    stream: &mut dyn MsgStream,
    dirty: &AtomicBool,
    watches: &[(u64, Arc<Table>, Arc<AtomicBool>)],
) -> Result<()> {
    if watches.is_empty() || !dirty.swap(false, Ordering::SeqCst) {
        return Ok(());
    }
    for (id, table, _alive) in watches {
        stream.send(Message::WatchUpdate {
            id: *id,
            table: table.name().to_string(),
            info: table.info(),
        })?;
    }
    stream.flush()
}

fn serve_connection(mut stream: Box<dyn MsgStream>, inner: Arc<ServerInner>) -> Result<()> {
    // Chunks streamed on this connection, awaiting item creation. On the
    // in-process transport these are the writer's own allocations — the
    // whole insert path is copy-free from client append to table item.
    let mut pending: HashMap<u64, ChunkHandle> = HashMap::new();
    let mut pending_order: std::collections::VecDeque<u64> = std::collections::VecDeque::new();
    // Watch subscriptions on this connection: (watch id, table, alive
    // flag). Watcher hooks flip the shared dirty bit; once the first
    // subscription lands, the loop switches to non-blocking reads with a
    // short tick so updates are pushed even with no request in flight.
    // Hooks hold only weak references, so a departed connection's hooks
    // unsubscribe themselves on their next firing.
    let mut watches: Vec<(u64, Arc<Table>, Arc<AtomicBool>)> = Vec::new();
    let dirty = Arc::new(AtomicBool::new(false));
    let mut nonblocking = false;

    loop {
        if inner.shutdown.load(Ordering::SeqCst) {
            return Ok(());
        }
        let msg = if nonblocking {
            match stream.try_recv() {
                Ok(Some(m)) => m,
                Ok(None) => {
                    flush_watch_updates(stream.as_mut(), &dirty, &watches)?;
                    std::thread::sleep(WATCH_TICK);
                    continue;
                }
                Err(Error::Io(_)) => return Ok(()), // client hung up
                Err(e) => return Err(e),
            }
        } else {
            match stream.recv() {
                Ok(m) => m,
                Err(Error::Io(_)) => return Ok(()), // client hung up
                Err(e) => return Err(e),
            }
        };
        match msg {
            Message::InsertChunks { chunks } => {
                stash_chunks(&inner, &mut pending, &mut pending_order, chunks);
                // No reply: chunk streaming is fire-and-forget, acks ride
                // on the subsequent CreateItem.
            }
            Message::CreateItem { id, item, timeout_ms } => {
                let started = Instant::now();
                let mut spans = ReqSpans::new(server_trace());
                let reply = (|| {
                    let table = inner.table(&item.table)?.clone();
                    let item = resolve_item(&inner, &pending, &item)?;
                    inner.gated_insert(
                        &table,
                        item,
                        Duration::from_millis(timeout_ms),
                        &mut spans,
                    )?;
                    Ok(())
                })();
                inner.record_insert_latency(&item.table, started);
                finish_spans(&inner, spans, &item.table, started);
                send_reply(stream.as_mut(), id, reply.map(|()| String::new()))?;
            }
            Message::CreateItemBatch { id, items, timeout_ms, trace } => {
                if items.len() > MAX_BATCH_OPS {
                    send_err(stream.as_mut(), id, &batch_too_large(items.len()))?;
                } else {
                    // Ops apply in order and fail independently; the
                    // blocking `gated_insert` IS the threaded model's
                    // park-at-the-blocked-op semantics (nothing after the
                    // blocked op runs until it resolves).
                    let timeout = Duration::from_millis(timeout_ms);
                    let batch_started = Instant::now();
                    let span_table = items
                        .first()
                        .map(|i| i.table.clone())
                        .unwrap_or_else(|| "_server".to_string());
                    let mut spans = ReqSpans::new(trace.or_else(server_trace));
                    let mut results = Vec::with_capacity(items.len());
                    for wire_item in &items {
                        let started = Instant::now();
                        let r = (|| {
                            let table = inner.table(&wire_item.table)?.clone();
                            let item = resolve_item(&inner, &pending, wire_item)?;
                            inner.gated_insert(&table, item, timeout, &mut spans)?;
                            Ok(String::new())
                        })();
                        inner.record_insert_latency(&wire_item.table, started);
                        results.push(BatchResult::from_result(r.as_ref().map(String::clone)));
                    }
                    // Only the client-stamped context is echoed; a
                    // server-promoted trace stays internal so untraced
                    // peers see byte-identical replies.
                    stream.send(Message::BatchReply { id, results, trace })?;
                    stream.flush()?;
                    finish_spans(&inner, spans, &span_table, batch_started);
                }
            }
            Message::SampleRequest {
                id,
                table,
                num_samples,
                timeout_ms,
            } => {
                let started = Instant::now();
                let mut spans = ReqSpans::new(server_trace());
                let result = (|| {
                    let table = inner.table(&table)?.clone();
                    inner.gated_sample(
                        &table,
                        num_samples.max(1) as usize,
                        Duration::from_millis(timeout_ms),
                        &mut spans,
                    )
                })();
                inner.record_sample_latency(&table, started);
                finish_spans(&inner, spans, &table, started);
                match result.and_then(|samples| sample_reply(id, &samples)) {
                    Ok(reply) => {
                        stream.send(reply)?;
                        stream.flush()?;
                    }
                    Err(e) => {
                        send_err(stream.as_mut(), id, &e)?;
                    }
                }
            }
            Message::MutatePriorities {
                id,
                table,
                updates,
                deletes,
            } => {
                let reply = (|| {
                    let table = inner.table(&table)?.clone();
                    let _guard = inner.gate.enter();
                    let updated = table.update_priorities(&updates)?;
                    let deleted = table.delete(&deletes)?;
                    Ok(format!("updated={updated} deleted={deleted}"))
                })();
                send_reply(stream.as_mut(), id, reply)?;
            }
            Message::PriorityUpdateBatch { id, ops, trace } => {
                if ops.len() > MAX_BATCH_OPS {
                    send_err(stream.as_mut(), id, &batch_too_large(ops.len()))?;
                } else {
                    let started = Instant::now();
                    let mut spans = ReqSpans::new(trace.or_else(server_trace));
                    // Mutations never park: one gate entry covers the whole
                    // batch, and each op's keys are already grouped per
                    // shard by `update_priorities`/`delete` — N ops cost one
                    // gate acquisition and one lock hold per touched shard.
                    let results = {
                        let (_guard, waited) = inner.gate.enter_timed();
                        spans.gate += waited;
                        let op_started = Instant::now();
                        let results: Vec<BatchResult> = ops
                            .iter()
                            .map(|op| {
                                let r = (|| {
                                    let table = inner.table(&op.table)?;
                                    let updated = table.update_priorities(&op.updates)?;
                                    let deleted = table.delete(&op.deletes)?;
                                    Ok(format!("updated={updated} deleted={deleted}"))
                                })();
                                BatchResult::from_result(r.as_ref().map(String::clone))
                            })
                            .collect();
                        spans.op_attempt(op_started.elapsed());
                        results
                    };
                    let span_table = ops
                        .first()
                        .map(|op| op.table.clone())
                        .unwrap_or_else(|| "_server".to_string());
                    stream.send(Message::BatchReply { id, results, trace })?;
                    stream.flush()?;
                    finish_spans(&inner, spans, &span_table, started);
                }
            }
            Message::Reset { id, table } => {
                let reply = (|| {
                    let table = inner.table(&table)?.clone();
                    let _guard = inner.gate.enter();
                    table.reset();
                    Ok(String::new())
                })();
                send_reply(stream.as_mut(), id, reply)?;
            }
            Message::InfoRequest { id } => {
                let tables = inner
                    .table_order
                    .iter()
                    .map(|t| (t.name().to_string(), t.info()))
                    .collect();
                stream.send(Message::Info { id, tables })?;
                stream.flush()?;
            }
            Message::Ping { id, nonce } => {
                stream.send(Message::Pong { id, nonce })?;
                stream.flush()?;
            }
            Message::Checkpoint { id } => {
                let reply = inner
                    .checkpoint()
                    .map(|p| p.display().to_string());
                send_reply(stream.as_mut(), id, reply)?;
            }
            Message::AdminReconfig {
                id,
                table,
                max_size,
                min_diff,
                max_diff,
                checkpoint_interval_ms,
                slow_request_micros,
                trace_sample_per_mille,
            } => {
                let reply = inner.apply_admin(
                    &table,
                    max_size,
                    min_diff,
                    max_diff,
                    checkpoint_interval_ms,
                    slow_request_micros,
                    trace_sample_per_mille,
                );
                send_reply(stream.as_mut(), id, reply)?;
            }
            Message::WatchRequest { id, table } => match inner.table(&table) {
                Ok(t) => {
                    let t = t.clone();
                    let alive = Arc::new(AtomicBool::new(true));
                    let hook_dirty = Arc::downgrade(&dirty);
                    let hook_alive = Arc::downgrade(&alive);
                    t.register_watcher(Box::new(move || {
                        let (Some(d), Some(a)) = (hook_dirty.upgrade(), hook_alive.upgrade())
                        else {
                            return false; // connection gone: unsubscribe
                        };
                        if !a.load(Ordering::SeqCst) {
                            return false; // cancelled: unsubscribe
                        }
                        d.store(true, Ordering::SeqCst);
                        true
                    }));
                    watches.push((id, t.clone(), alive));
                    if !nonblocking {
                        stream.set_nonblocking(true)?;
                        nonblocking = true;
                    }
                    // Immediate snapshot: the subscriber has a baseline
                    // before the first delta.
                    stream.send(Message::WatchUpdate {
                        id,
                        table,
                        info: t.info(),
                    })?;
                    stream.flush()?;
                }
                Err(e) => send_err(stream.as_mut(), id, &e)?,
            },
            Message::WatchCancel { id } => {
                let before = watches.len();
                watches.retain(|(wid, _, alive)| {
                    if *wid == id {
                        alive.store(false, Ordering::SeqCst);
                        false
                    } else {
                        true
                    }
                });
                // Idempotent: cancelling an unknown id acks with n=0.
                send_reply(
                    stream.as_mut(),
                    id,
                    Ok(format!("cancelled={}", before - watches.len())),
                )?;
            }
            // Server-to-client messages arriving at the server are protocol
            // violations.
            Message::Ack { .. }
            | Message::Err { .. }
            | Message::SampleData { .. }
            | Message::Info { .. }
            | Message::WatchUpdate { .. }
            | Message::BatchReply { .. }
            | Message::Pong { .. } => {
                return Err(Error::Decode("client sent a server-side message".into()));
            }
        }
        // A mutation handled above may have dirtied this connection's own
        // subscriptions: push before reading the next frame so the
        // reply/update order per request is deterministic (and matches the
        // event model's per-service-pass emission).
        flush_watch_updates(stream.as_mut(), &dirty, &watches)?;
    }
}

/// The per-frame rejection for batches beyond [`MAX_BATCH_OPS`]: a clean
/// `Err` reply (code `INVALID`), never a decode failure — a misconfigured
/// client keeps a usable connection. Shared by both service models.
pub(crate) fn batch_too_large(n: usize) -> Error {
    Error::InvalidArgument(format!("batch of {n} ops exceeds server cap {MAX_BATCH_OPS}"))
}

fn send_reply(stream: &mut dyn MsgStream, id: u64, result: Result<String>) -> Result<()> {
    let msg = match result {
        Ok(detail) => Message::Ack { id, detail },
        Err(e) => Message::Err {
            id,
            code: error_code(&e),
            message: e.to_string(),
        },
    };
    stream.send(msg)?;
    stream.flush()
}

fn send_err(stream: &mut dyn MsgStream, id: u64, e: &Error) -> Result<()> {
    stream.send(Message::Err {
        id,
        code: error_code(e),
        message: e.to_string(),
    })?;
    stream.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::chunk::Compression;
    use crate::core::tensor::Tensor;
    use crate::net::wire::{Message, PriorityUpdateOp};
    use std::io::{BufReader, BufWriter, Write};

    fn mk_chunk(key: u64, v: f32) -> Arc<Chunk> {
        let steps = vec![vec![Tensor::from_f32(&[1], &[v]).unwrap()]];
        Arc::new(Chunk::from_steps(key, 0, &steps, Compression::None).unwrap())
    }

    fn start_server() -> Server {
        Server::builder()
            .table(TableConfig::uniform_replay("replay", 100))
            .table(TableConfig::queue("queue", 4))
            .bind("127.0.0.1:0")
            .unwrap()
    }

    /// Raw-protocol round trip over plain TCP framing (the typed Client is
    /// tested in client/; both transports are covered by the conformance
    /// suite in tests/transport_conformance.rs).
    #[test]
    fn raw_insert_then_sample_over_tcp() {
        let server = start_server();
        let stream = TcpStream::connect(server.local_addr()).unwrap();
        stream.set_nodelay(true).unwrap();
        let mut r = BufReader::new(stream.try_clone().unwrap());
        let mut w = BufWriter::new(stream);

        Message::InsertChunks {
            chunks: vec![mk_chunk(11, 3.5)],
        }
        .write_frame(&mut w)
        .unwrap();
        Message::CreateItem {
            id: 1,
            item: WireItem {
                key: 7,
                table: "replay".into(),
                priority: 1.0,
                chunk_keys: vec![11],
                offset: 0,
                length: 1,
                times_sampled: 0,
                columns: None,
            },
            timeout_ms: 1000,
        }
        .write_frame(&mut w)
        .unwrap();
        w.flush().unwrap();
        match Message::read_frame(&mut r).unwrap() {
            Message::Ack { id, .. } => assert_eq!(id, 1),
            other => panic!("expected ack, got {other:?}"),
        }

        Message::SampleRequest {
            id: 2,
            table: "replay".into(),
            num_samples: 1,
            timeout_ms: 1000,
        }
        .write_frame(&mut w)
        .unwrap();
        w.flush().unwrap();
        match Message::read_frame(&mut r).unwrap() {
            Message::SampleData { id, infos, chunks } => {
                assert_eq!(id, 2);
                assert_eq!(infos[0].item.key, 7);
                assert_eq!(chunks[0].key, 11);
                let steps = chunks[0].to_steps().unwrap();
                assert_eq!(steps[0][0].to_f32().unwrap(), vec![3.5]);
            }
            other => panic!("expected samples, got {other:?}"),
        }
    }

    /// The same raw round trip over the in-process transport, proving both
    /// backends speak the identical protocol — and that the sampled chunk
    /// is the very allocation the server holds (zero-copy).
    #[test]
    fn raw_insert_then_sample_in_proc() {
        let server = start_server();
        let mut conn = transport::dial(&server.in_proc_addr()).unwrap();
        let sent = mk_chunk(21, 9.25);
        conn.send(Message::InsertChunks {
            chunks: vec![sent.clone()],
        })
        .unwrap();
        conn.send(Message::CreateItem {
            id: 1,
            item: WireItem {
                key: 9,
                table: "replay".into(),
                priority: 1.0,
                chunk_keys: vec![21],
                offset: 0,
                length: 1,
                times_sampled: 0,
                columns: None,
            },
            timeout_ms: 1000,
        })
        .unwrap();
        conn.flush().unwrap();
        match conn.recv().unwrap() {
            Message::Ack { id, .. } => assert_eq!(id, 1),
            other => panic!("expected ack, got {other:?}"),
        }

        conn.send(Message::SampleRequest {
            id: 2,
            table: "replay".into(),
            num_samples: 1,
            timeout_ms: 1000,
        })
        .unwrap();
        conn.flush().unwrap();
        match conn.recv().unwrap() {
            Message::SampleData { id, infos, chunks } => {
                assert_eq!(id, 2);
                assert_eq!(infos[0].item.key, 9);
                assert!(
                    Arc::ptr_eq(&chunks[0], &sent),
                    "in-proc sample must share the inserted chunk allocation"
                );
            }
            other => panic!("expected samples, got {other:?}"),
        }
    }

    #[test]
    fn in_proc_only_server_serves_and_reports_no_tcp() {
        let server = Server::builder()
            .table(TableConfig::uniform_replay("t", 10))
            .serve_in_proc()
            .unwrap();
        assert!(server.tcp_addr().is_none());
        let mut conn = transport::dial(&server.in_proc_addr()).unwrap();
        conn.send(Message::InfoRequest { id: 5 }).unwrap();
        conn.flush().unwrap();
        match conn.recv().unwrap() {
            Message::Info { tables, .. } => assert_eq!(tables[0].0, "t"),
            other => panic!("expected info, got {other:?}"),
        }
    }

    #[test]
    fn unknown_table_errors() {
        let server = start_server();
        let stream = TcpStream::connect(server.local_addr()).unwrap();
        let mut r = BufReader::new(stream.try_clone().unwrap());
        let mut w = BufWriter::new(stream);
        Message::SampleRequest {
            id: 1,
            table: "nope".into(),
            num_samples: 1,
            timeout_ms: 10,
        }
        .write_frame(&mut w)
        .unwrap();
        w.flush().unwrap();
        match Message::read_frame(&mut r).unwrap() {
            Message::Err { code, .. } => assert_eq!(code, crate::net::wire::code::NOT_FOUND),
            other => panic!("expected err, got {other:?}"),
        }
    }

    #[test]
    fn sample_timeout_maps_to_timeout_code() {
        let server = start_server();
        let stream = TcpStream::connect(server.local_addr()).unwrap();
        let mut r = BufReader::new(stream.try_clone().unwrap());
        let mut w = BufWriter::new(stream);
        Message::SampleRequest {
            id: 1,
            table: "replay".into(),
            num_samples: 1,
            timeout_ms: 30,
        }
        .write_frame(&mut w)
        .unwrap();
        w.flush().unwrap();
        match Message::read_frame(&mut r).unwrap() {
            Message::Err { code, .. } => assert_eq!(code, crate::net::wire::code::TIMEOUT),
            other => panic!("expected timeout, got {other:?}"),
        }
    }

    #[test]
    fn info_request_reports_tables() {
        let server = start_server();
        let stream = TcpStream::connect(server.local_addr()).unwrap();
        let mut r = BufReader::new(stream.try_clone().unwrap());
        let mut w = BufWriter::new(stream);
        Message::InfoRequest { id: 5 }.write_frame(&mut w).unwrap();
        w.flush().unwrap();
        match Message::read_frame(&mut r).unwrap() {
            Message::Info { tables, .. } => {
                let names: Vec<&str> = tables.iter().map(|(n, _)| n.as_str()).collect();
                assert_eq!(names, vec!["replay", "queue"]);
            }
            other => panic!("expected info, got {other:?}"),
        }
    }

    #[test]
    fn stop_releases_blocked_clients() {
        let mut server = start_server();
        let addr = server.local_addr();
        let h = std::thread::spawn(move || {
            let stream = TcpStream::connect(addr).unwrap();
            let mut r = BufReader::new(stream.try_clone().unwrap());
            let mut w = BufWriter::new(stream);
            Message::SampleRequest {
                id: 1,
                table: "replay".into(),
                num_samples: 1,
                timeout_ms: 60_000,
            }
            .write_frame(&mut w)
            .unwrap();
            w.flush().unwrap();
            Message::read_frame(&mut r)
        });
        std::thread::sleep(Duration::from_millis(50));
        server.stop();
        match h.join().unwrap() {
            Ok(Message::Err { code, .. }) => {
                assert_eq!(code, crate::net::wire::code::CANCELLED)
            }
            Ok(other) => panic!("unexpected {other:?}"),
            // Connection torn down before the reply is also acceptable.
            Err(Error::Io(_)) => {}
            Err(e) => panic!("unexpected error {e}"),
        }
    }

    #[test]
    fn stop_unbinds_in_proc_endpoint() {
        let mut server = Server::builder()
            .table(TableConfig::uniform_replay("t", 10))
            .serve_in_proc()
            .unwrap();
        let addr = server.in_proc_addr();
        assert!(transport::dial(&addr).is_ok());
        server.stop();
        assert!(transport::dial(&addr).is_err(), "endpoint must be unbound");
    }

    #[test]
    fn periodic_checkpointing_writes_files() {
        let dir = std::env::temp_dir().join(format!("reverb_periodic_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let server = Server::builder()
            .table(TableConfig::uniform_replay("t", 10))
            .checkpoint_dir(&dir)
            .checkpoint_interval(Duration::from_millis(60))
            .bind("127.0.0.1:0")
            .unwrap();
        // Write something so the checkpoints have content.
        let table = server.table("t").unwrap();
        let steps = vec![vec![crate::core::tensor::Tensor::from_f32(&[1], &[1.0]).unwrap()]];
        let chunk = std::sync::Arc::new(
            Chunk::from_steps(1, 0, &steps, Compression::None).unwrap(),
        );
        table
            .insert_or_assign(
                crate::core::item::Item::new(1, "t", 1.0, vec![chunk], 0, 1).unwrap(),
                None,
            )
            .unwrap();
        std::thread::sleep(Duration::from_millis(250));
        drop(server);
        let ckpts: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.path().extension().is_some_and(|x| x == "rvb"))
            .collect();
        assert!(ckpts.len() >= 2, "expected periodic checkpoints, got {}", ckpts.len());
        // And the newest one restores.
        let newest = ckpts.iter().map(|e| e.path()).max().unwrap();
        let restored = Server::builder()
            .table(TableConfig::uniform_replay("t", 10))
            .load_checkpoint(newest)
            .bind("127.0.0.1:0")
            .unwrap();
        assert_eq!(restored.table("t").unwrap().size(), 1);
        std::fs::remove_dir_all(dir).ok();
    }

    fn mk_flat_item(key: u64, table: &str, priority: f64) -> crate::core::item::Item {
        crate::core::item::Item::new(
            key,
            table,
            priority,
            vec![mk_chunk(key + 500, key as f32)],
            0,
            1,
        )
        .unwrap()
    }

    #[test]
    fn incremental_checkpoint_restores_through_standard_load() {
        let dir = std::env::temp_dir().join(format!(
            "reverb_persist_srv_{}",
            std::process::id()
        ));
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        let server = Server::builder()
            .table(TableConfig::uniform_replay("t", 100))
            .checkpoint_dir(&dir)
            .persist_mode(PersistMode::incremental())
            .serve_in_proc()
            .unwrap();
        let table = server.table("t").unwrap();
        for k in 1..=10 {
            table
                .insert_or_assign(mk_flat_item(k, "t", k as f64), None)
                .unwrap();
        }
        table.update_priorities(&[(3, 99.0)]).unwrap();
        table.delete(&[5]).unwrap();
        let manifest = server.checkpoint().unwrap();
        assert!(manifest.ends_with(crate::persist::MANIFEST_NAME));
        // A mutation after the manifest commit becomes durable via the
        // final rotation at shutdown.
        table
            .insert_or_assign(mk_flat_item(11, "t", 1.0), None)
            .unwrap();
        drop(server);

        let restored = Server::builder()
            .table(TableConfig::uniform_replay("t", 100))
            .load_checkpoint(dir.join(crate::persist::MANIFEST_NAME))
            .serve_in_proc()
            .unwrap();
        let rt = restored.table("t").unwrap();
        assert_eq!(rt.size(), 10, "10 inserts - 1 delete + 1 late insert");
        assert!(!rt.contains(5));
        assert!(rt.contains(11));
        let (items, inserts, _samples) = rt.snapshot();
        assert_eq!(inserts, 11, "insert counter restored exactly");
        let p3 = items.iter().find(|i| i.key == 3).unwrap();
        assert_eq!(p3.priority, 99.0, "priority update replayed");
        // Payloads decode after restore.
        let s = rt.sample(None).unwrap();
        assert!(s.item.materialize().is_ok());
        drop(restored);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn incremental_restart_without_load_restores_automatically() {
        let dir = std::env::temp_dir().join(format!(
            "reverb_persist_autorestore_{}",
            std::process::id()
        ));
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        let mk = || {
            Server::builder()
                .table(TableConfig::uniform_replay("t", 100))
                .checkpoint_dir(&dir)
                .persist_mode(PersistMode::incremental())
                .serve_in_proc()
                .unwrap()
        };
        let server = mk();
        let table = server.table("t").unwrap();
        for k in 1..=3 {
            table
                .insert_or_assign(mk_flat_item(k, "t", 1.0), None)
                .unwrap();
        }
        server.checkpoint().unwrap();
        drop(server);
        // A plain restart (same flags, no explicit load) must restore the
        // chain rather than wipe it.
        let restarted = mk();
        assert_eq!(restarted.table("t").unwrap().size(), 3);
        drop(restarted);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn incremental_requires_checkpoint_dir() {
        let r = Server::builder()
            .table(TableConfig::uniform_replay("t", 10))
            .persist_mode(PersistMode::incremental())
            .serve_in_proc();
        assert!(r.is_err());
    }

    #[test]
    fn periodic_incremental_commits_manifest() {
        let dir = std::env::temp_dir().join(format!(
            "reverb_persist_periodic_{}",
            std::process::id()
        ));
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        let server = Server::builder()
            .table(TableConfig::uniform_replay("t", 10))
            .checkpoint_dir(&dir)
            .persist_mode(PersistMode::incremental())
            .checkpoint_interval(Duration::from_millis(60))
            .serve_in_proc()
            .unwrap();
        let table = server.table("t").unwrap();
        table
            .insert_or_assign(mk_flat_item(1, "t", 1.0), None)
            .unwrap();
        std::thread::sleep(Duration::from_millis(300));
        let m = crate::persist::manifest::read_manifest(
            &dir.join(crate::persist::MANIFEST_NAME),
        )
        .unwrap();
        assert!(m.watermark >= 1, "periodic rotation committed the insert");
        drop(server);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn stop_returns_quickly_under_long_checkpoint_interval() {
        // Regression: the checkpoint thread used to tick with
        // `thread::sleep`, so stop() could block for up to the interval.
        // It now parks on a condvar signalled by stop().
        let dir = std::env::temp_dir().join(format!(
            "reverb_stop_latency_{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let mut server = Server::builder()
            .table(TableConfig::uniform_replay("t", 10))
            .checkpoint_dir(&dir)
            .checkpoint_interval(Duration::from_secs(3600))
            .bind("127.0.0.1:0")
            .unwrap();
        // Let the checkpoint thread reach its park.
        std::thread::sleep(Duration::from_millis(50));
        let start = Instant::now();
        server.stop();
        let elapsed = start.elapsed();
        assert!(
            elapsed < Duration::from_millis(100),
            "stop took {elapsed:?} under a 1h checkpoint interval"
        );
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn threaded_service_model_still_serves() {
        // The differential-testing oracle: the legacy model must keep
        // speaking the identical protocol.
        let server = Server::builder()
            .table(TableConfig::uniform_replay("t", 100))
            .service_model(ServiceModel::Threaded)
            .bind("127.0.0.1:0")
            .unwrap();
        assert!(server.live_connections().is_none(), "threaded model");
        let mut conn = transport::dial(&format!("tcp://{}", server.local_addr())).unwrap();
        conn.send(Message::InsertChunks { chunks: vec![mk_chunk(31, 1.5)] })
            .unwrap();
        conn.send(Message::CreateItem {
            id: 1,
            item: WireItem {
                key: 3,
                table: "t".into(),
                priority: 1.0,
                chunk_keys: vec![31],
                offset: 0,
                length: 1,
                times_sampled: 0,
                columns: None,
            },
            timeout_ms: 1000,
        })
        .unwrap();
        conn.flush().unwrap();
        assert!(matches!(conn.recv().unwrap(), Message::Ack { id: 1, .. }));
        conn.send(Message::SampleRequest {
            id: 2,
            table: "t".into(),
            num_samples: 1,
            timeout_ms: 1000,
        })
        .unwrap();
        conn.flush().unwrap();
        match conn.recv().unwrap() {
            Message::SampleData { id, infos, .. } => {
                assert_eq!(id, 2);
                assert_eq!(infos[0].item.key, 3);
            }
            other => panic!("expected samples, got {other:?}"),
        }
    }

    #[test]
    fn event_model_with_one_worker_parks_blocked_insert_without_pinning() {
        // The core non-pinning property: with a single service worker, a
        // corridor-blocked CreateItem on connection A must not prevent
        // connection B from being serviced — and B's sample must unblock
        // A's parked insert through the table wakers.
        let server = Server::builder()
            .table(TableConfig::queue("q", 1))
            .service_threads(1)
            .serve_in_proc()
            .unwrap();
        let mk_create = |id: u64, key: u64| Message::CreateItem {
            id,
            item: WireItem {
                key,
                table: "q".into(),
                priority: 1.0,
                chunk_keys: vec![key + 100],
                offset: 0,
                length: 1,
                times_sampled: 0,
                columns: None,
            },
            timeout_ms: 10_000,
        };
        let mut a = transport::dial(&server.in_proc_addr()).unwrap();
        a.send(Message::InsertChunks { chunks: vec![mk_chunk(101, 1.0)] })
            .unwrap();
        a.send(mk_create(1, 1)).unwrap();
        a.flush().unwrap();
        assert!(matches!(a.recv().unwrap(), Message::Ack { id: 1, .. }));
        // Queue full: this one parks server-side.
        a.send(Message::InsertChunks { chunks: vec![mk_chunk(102, 2.0)] })
            .unwrap();
        a.send(mk_create(2, 2)).unwrap();
        a.flush().unwrap();
        // The single worker must still serve connection B while A parks.
        let mut b = transport::dial(&server.in_proc_addr()).unwrap();
        b.send(Message::SampleRequest {
            id: 7,
            table: "q".into(),
            num_samples: 1,
            timeout_ms: 5_000,
        })
        .unwrap();
        b.flush().unwrap();
        match b.recv().unwrap() {
            Message::SampleData { id, infos, .. } => {
                assert_eq!(id, 7);
                assert_eq!(infos[0].item.key, 1);
            }
            other => panic!("expected samples, got {other:?}"),
        }
        // The consume-on-sample freed the corridor: A's parked insert
        // completes via the re-arm hook.
        assert!(matches!(a.recv().unwrap(), Message::Ack { id: 2, .. }));
        assert_eq!(server.table("q").unwrap().size(), 1);
        assert_eq!(server.live_connections(), Some(2));
    }

    /// Run a fixed, fully deterministic protocol script and log every
    /// reply (the differential-testing oracle for the two service models).
    /// `use_tcp` picks the socket path (partial frames, writev queue) vs
    /// the in-proc channel path (occupancy wakers) — both must agree.
    fn run_differential_script(model: ServiceModel, use_tcp: bool) -> Vec<String> {
        fn describe(m: Message) -> String {
            match m {
                Message::Ack { id, .. } => format!("ack {id}"),
                Message::Err { id, code, .. } => format!("err {id} code={code}"),
                Message::SampleData { id, infos, .. } => format!(
                    "samples {id} keys={:?}",
                    infos.iter().map(|i| i.item.key).collect::<Vec<_>>()
                ),
                Message::Info { id, tables } => format!(
                    "info {id} {:?}",
                    tables
                        .iter()
                        .map(|(n, i)| (n.clone(), i.size))
                        .collect::<Vec<_>>()
                ),
                Message::WatchUpdate { id, table, info } => {
                    format!("watch {id} {table} size={}", info.size)
                }
                Message::BatchReply { id, results, .. } => format!(
                    "batch {id} [{}]",
                    results
                        .iter()
                        .map(|r| match r {
                            BatchResult::Ok { detail } => format!("ok:{detail}"),
                            BatchResult::Err { code, .. } => format!("err:{code}"),
                        })
                        .collect::<Vec<_>>()
                        .join(",")
                ),
                other => format!("unexpected {other:?}"),
            }
        }
        let server = Server::builder()
            .table(TableConfig::queue("q", 2))
            .service_model(model)
            .bind("127.0.0.1:0")
            .unwrap();
        let addr = if use_tcp {
            format!("tcp://{}", server.local_addr())
        } else {
            server.in_proc_addr()
        };
        let mut conn = transport::dial(&addr).unwrap();
        let item = |key: u64| WireItem {
            key,
            table: "q".into(),
            priority: 1.0,
            chunk_keys: vec![key + 200],
            offset: 0,
            length: 1,
            times_sampled: 0,
            columns: None,
        };
        let mut log = Vec::new();
        for k in 1..=2u64 {
            conn.send(Message::InsertChunks { chunks: vec![mk_chunk(k + 200, k as f32)] })
                .unwrap();
            conn.send(Message::CreateItem { id: k, item: item(k), timeout_ms: 2_000 })
                .unwrap();
        }
        conn.flush().unwrap();
        for _ in 0..2 {
            log.push(describe(conn.recv().unwrap()));
        }
        // Full queue: the third insert times out (and must be replied
        // before anything later on this connection — FIFO per conn).
        conn.send(Message::InsertChunks { chunks: vec![mk_chunk(203, 3.0)] })
            .unwrap();
        conn.send(Message::CreateItem { id: 3, item: item(3), timeout_ms: 50 })
            .unwrap();
        conn.send(Message::SampleRequest {
            id: 4,
            table: "q".into(),
            num_samples: 2,
            timeout_ms: 2_000,
        })
        .unwrap();
        conn.flush().unwrap();
        log.push(describe(conn.recv().unwrap()));
        log.push(describe(conn.recv().unwrap()));
        // Drained queue: sampling times out.
        conn.send(Message::SampleRequest {
            id: 5,
            table: "q".into(),
            num_samples: 1,
            timeout_ms: 50,
        })
        .unwrap();
        // Unknown table, reset, info.
        conn.send(Message::MutatePriorities {
            id: 6,
            table: "nope".into(),
            updates: vec![],
            deletes: vec![],
        })
        .unwrap();
        conn.send(Message::Reset { id: 7, table: "q".into() }).unwrap();
        conn.send(Message::InfoRequest { id: 8 }).unwrap();
        conn.flush().unwrap();
        for _ in 0..4 {
            log.push(describe(conn.recv().unwrap()));
        }
        // --- Observability/control plane, same determinism bar. Watch
        // pushes are coalesced per service pass (latest-wins), so the
        // script sends ONE mutation at a time and drains its frames
        // before the next — pipelined mutations would legitimately
        // coalesce differently across the two models.
        conn.send(Message::AdminReconfig {
            id: 9,
            table: "q".into(),
            max_size: Some(3),
            min_diff: None,
            max_diff: None,
            checkpoint_interval_ms: None,
            slow_request_micros: None,
            trace_sample_per_mille: None,
        })
        .unwrap();
        // Half a corridor: rejected, nothing applied.
        conn.send(Message::AdminReconfig {
            id: 10,
            table: "q".into(),
            max_size: None,
            min_diff: Some(0.0),
            max_diff: None,
            checkpoint_interval_ms: None,
            slow_request_micros: None,
            trace_sample_per_mille: None,
        })
        .unwrap();
        conn.send(Message::WatchRequest { id: 11, table: "q".into() }).unwrap();
        conn.flush().unwrap();
        for _ in 0..3 {
            log.push(describe(conn.recv().unwrap()));
        }
        // One insert: its ack, then the coalesced watch push.
        conn.send(Message::InsertChunks { chunks: vec![mk_chunk(204, 4.0)] })
            .unwrap();
        conn.send(Message::CreateItem { id: 12, item: item(4), timeout_ms: 2_000 })
            .unwrap();
        conn.flush().unwrap();
        log.push(describe(conn.recv().unwrap()));
        log.push(describe(conn.recv().unwrap()));
        // Cancel the subscription: later mutations push nothing.
        conn.send(Message::WatchCancel { id: 11 }).unwrap();
        conn.flush().unwrap();
        log.push(describe(conn.recv().unwrap()));
        conn.send(Message::InsertChunks { chunks: vec![mk_chunk(205, 5.0)] })
            .unwrap();
        conn.send(Message::CreateItem { id: 13, item: item(5), timeout_ms: 2_000 })
            .unwrap();
        conn.send(Message::InfoRequest { id: 14 }).unwrap();
        conn.flush().unwrap();
        log.push(describe(conn.recv().unwrap()));
        log.push(describe(conn.recv().unwrap()));
        // --- Wire v3 (DESIGN.md §13): batched frames, reply-for-reply.
        // Queue state here: items {4, 5}, max_size 3 (admin-raised) — one
        // free slot. The batch fills it (ok), hits an unknown table
        // (per-op err), then blocks on the full queue until the 50 ms
        // deadline (per-op timeout err): one frame exercising success,
        // failure, and the park/timeout path in one deterministic reply.
        conn.send(Message::InsertChunks {
            chunks: vec![mk_chunk(206, 6.0), mk_chunk(207, 7.0)],
        })
        .unwrap();
        let mut bad = item(6);
        bad.table = "nope".into();
        conn.send(Message::CreateItemBatch {
            id: 15,
            items: vec![item(6), bad, item(7)],
            timeout_ms: 50,
            trace: None,
        })
        .unwrap();
        conn.flush().unwrap();
        log.push(describe(conn.recv().unwrap()));
        // Batched mutations under one id: an update+delete op (applied in
        // order), then an unknown-table op (independent per-op failure).
        conn.send(Message::PriorityUpdateBatch {
            id: 16,
            ops: vec![
                PriorityUpdateOp {
                    table: "q".into(),
                    updates: vec![(4, 9.0)],
                    deletes: vec![5],
                },
                PriorityUpdateOp {
                    table: "nope".into(),
                    updates: vec![],
                    deletes: vec![],
                },
            ],
            trace: None,
        })
        .unwrap();
        // An oversized batch draws a clean per-frame error and leaves the
        // connection usable (the InfoRequest after it still answers).
        conn.send(Message::PriorityUpdateBatch {
            id: 17,
            ops: vec![
                PriorityUpdateOp {
                    table: "q".into(),
                    updates: vec![],
                    deletes: vec![],
                };
                crate::net::wire::MAX_BATCH_OPS + 1
            ],
            trace: None,
        })
        .unwrap();
        conn.send(Message::InfoRequest { id: 18 }).unwrap();
        conn.flush().unwrap();
        for _ in 0..3 {
            log.push(describe(conn.recv().unwrap()));
        }
        log
    }

    #[test]
    fn service_models_are_behaviourally_identical() {
        let expected = vec![
            "ack 1".to_string(),
            "ack 2".to_string(),
            "err 3 code=2".to_string(),
            "samples 4 keys=[1, 2]".to_string(),
            "err 5 code=2".to_string(),
            "err 6 code=1".to_string(),
            "ack 7".to_string(),
            "info 8 [(\"q\", 0)]".to_string(),
            "ack 9".to_string(),
            "err 10 code=4".to_string(),
            "watch 11 q size=0".to_string(),
            "ack 12".to_string(),
            "watch 11 q size=1".to_string(),
            "ack 11".to_string(),
            "ack 13".to_string(),
            "info 14 [(\"q\", 2)]".to_string(),
            "batch 15 [ok:,err:1,err:2]".to_string(),
            "batch 16 [ok:updated=1 deleted=1,err:1]".to_string(),
            "err 17 code=4".to_string(),
            "info 18 [(\"q\", 2)]".to_string(),
        ];
        // Both models × both transport paths (TCP exercises partial
        // frames and the writev queue; in-proc the occupancy wakers).
        for use_tcp in [false, true] {
            let threaded = run_differential_script(ServiceModel::Threaded, use_tcp);
            let event = run_differential_script(ServiceModel::Event, use_tcp);
            assert_eq!(threaded, event, "oracle diverged (tcp={use_tcp})");
            assert_eq!(threaded, expected, "tcp={use_tcp}");
        }
    }

    #[cfg(unix)]
    #[test]
    fn uds_listener_serves_and_cleans_up() {
        let path = std::env::temp_dir().join(format!(
            "reverb_uds_server_{}.sock",
            std::process::id()
        ));
        std::fs::remove_file(&path).ok();
        let mut server = Server::builder()
            .table(TableConfig::uniform_replay("t", 100))
            .unix_socket(&path)
            .serve_in_proc()
            .unwrap();
        let addr = server.uds_addr().expect("uds endpoint");
        assert!(addr.starts_with(crate::net::transport::UNIX_SCHEME));
        let mut conn = transport::dial(&addr).unwrap();
        conn.send(Message::InfoRequest { id: 4 }).unwrap();
        conn.flush().unwrap();
        match conn.recv().unwrap() {
            Message::Info { id, tables } => {
                assert_eq!(id, 4);
                assert_eq!(tables[0].0, "t");
            }
            other => panic!("expected info, got {other:?}"),
        }
        server.stop();
        assert!(!path.exists(), "socket file removed at shutdown");
    }

    #[test]
    fn duplicate_table_rejected() {
        let r = Server::builder()
            .table(TableConfig::uniform_replay("t", 10))
            .table(TableConfig::uniform_replay("t", 10))
            .bind("127.0.0.1:0");
        assert!(r.is_err());
    }

    #[test]
    fn named_in_proc_endpoint_and_duplicate_name_rejected() {
        let server = Server::builder()
            .table(TableConfig::uniform_replay("t", 10))
            .in_proc_name("named-endpoint-test")
            .serve_in_proc()
            .unwrap();
        assert_eq!(
            server.in_proc_addr(),
            format!("{}named-endpoint-test", crate::net::transport::IN_PROC_SCHEME)
        );
        let dup = Server::builder()
            .table(TableConfig::uniform_replay("t", 10))
            .in_proc_name("named-endpoint-test")
            .serve_in_proc();
        assert!(dup.is_err());
    }
}
