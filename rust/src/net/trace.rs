//! End-to-end request tracing (DESIGN.md §15): wire-propagated span
//! context, per-stage timing, and an always-on flight recorder.
//!
//! Three cooperating pieces:
//!
//! 1. **[`TraceContext`]** — a `(trace_id, span_id, sampled)` triple that
//!    rides wire-v3 batch frames behind an envelope flag bit
//!    (`net::wire`). A request stamped by a client [`crate::Pipeline`]
//!    keeps one trace id across the fabric's member fan-out and the
//!    server's stage spans, so one id ties the whole chain together.
//! 2. **The flight recorder** — a process-global, lock-free ring of
//!    fixed-size span slots ([`Recorder`]). Every stage measurement is
//!    written with a seqlock per slot (writers never block, readers
//!    discard torn slots), striped over lanes keyed by thread so
//!    concurrent workers do not contend on a head pointer. Merge happens
//!    on read: `/trace` concatenates the lanes, sorts by start time, and
//!    renders Chrome trace-event JSON.
//! 3. **Global knobs** — the slow-request threshold (span chains above it
//!    are promoted to `log::warn!`) and client/server sampling rates,
//!    all plain atomics so the admin RPC can re-tune them live.
//!
//! The recorder is process-global rather than per-server on purpose: an
//! in-process client (`reverb://in-proc/...`) and its server share one
//! address space, and a single `/trace` dump should show the client
//! submit span next to the server's decode→gate→lock→execute→flush chain
//! for the same trace id.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::{Duration, Instant};

/// Span context carried on wire-v3 batch frames (and echoed on their
/// replies). `sampled` marks the trace as explicitly requested by a
/// client — unsampled requests still hit the flight recorder, but only
/// sampled ones are stamped with a non-zero trace id end to end.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TraceContext {
    pub trace_id: u64,
    pub span_id: u64,
    pub sampled: bool,
}

impl TraceContext {
    /// Mint a fresh root context (new trace id, new span id).
    pub fn generate() -> TraceContext {
        TraceContext {
            trace_id: next_id(),
            span_id: next_id(),
            sampled: true,
        }
    }

    /// A child context: same trace, fresh span id.
    pub fn child(&self) -> TraceContext {
        TraceContext {
            trace_id: self.trace_id,
            span_id: next_id(),
            sampled: self.sampled,
        }
    }
}

/// Globally-unique-enough id source: a process counter scrambled through
/// splitmix64 so ids from concurrent clients interleave without a
/// coordinated namespace.
fn next_id() -> u64 {
    static SEQ: AtomicU64 = AtomicU64::new(0x9E37_79B9_0000_0001);
    crate::util::splitmix64(SEQ.fetch_add(1, Ordering::Relaxed))
}

/// One pipeline stage a request passes through. Server stages (the first
/// seven) also feed the `reverb_stage_duration_seconds` histograms on
/// `/metrics`; client/fabric stages exist in the flight recorder only.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum Stage {
    /// Frame bytes → `Message` (event core read path).
    Decode = 0,
    /// Time between a connection becoming ready and a worker servicing it.
    Queue = 1,
    /// Parked time: checkpoint-gate closure plus rate-limiter corridor
    /// parks (both service models attribute all blocked time here).
    Gate = 2,
    /// Shard-mutex acquisition wait inside the table.
    Lock = 3,
    /// Table op execution (insert/sample/update) net of lock and journal.
    Execute = 4,
    /// Durability sink (persist journal append) time.
    Journal = 5,
    /// Reply serialization + socket write.
    Flush = 6,
    /// Client: request build + buffered send.
    Submit = 7,
    /// Client: explicit pipeline flush.
    ClientFlush = 8,
    /// Client: blocking flush+recv that produced a reply.
    Reply = 9,
    /// Fabric: owner-member pick + per-member send.
    Pick = 10,
    /// Fabric: re-route of a batch fragment after a member died.
    Reroute = 11,
}

/// The server-side stages exported as `/metrics` histogram families, in
/// render order.
pub const SERVER_STAGES: [Stage; 7] = [
    Stage::Decode,
    Stage::Queue,
    Stage::Gate,
    Stage::Lock,
    Stage::Execute,
    Stage::Journal,
    Stage::Flush,
];

impl Stage {
    pub fn name(self) -> &'static str {
        match self {
            Stage::Decode => "decode",
            Stage::Queue => "queue",
            Stage::Gate => "gate",
            Stage::Lock => "lock",
            Stage::Execute => "execute",
            Stage::Journal => "journal",
            Stage::Flush => "flush",
            Stage::Submit => "submit",
            Stage::ClientFlush => "client_flush",
            Stage::Reply => "reply",
            Stage::Pick => "pick",
            Stage::Reroute => "reroute",
        }
    }

    fn from_u8(v: u8) -> Option<Stage> {
        Some(match v {
            0 => Stage::Decode,
            1 => Stage::Queue,
            2 => Stage::Gate,
            3 => Stage::Lock,
            4 => Stage::Execute,
            5 => Stage::Journal,
            6 => Stage::Flush,
            7 => Stage::Submit,
            8 => Stage::ClientFlush,
            9 => Stage::Reply,
            10 => Stage::Pick,
            11 => Stage::Reroute,
            _ => return None,
        })
    }

    /// Index into per-table [`SERVER_STAGES`] histogram arrays.
    pub fn server_index(self) -> Option<usize> {
        let i = self as u8 as usize;
        (i < SERVER_STAGES.len()).then_some(i)
    }
}

// ---------------------------------------------------------------------
// global tuning knobs (admin RPC re-tunes these live)
// ---------------------------------------------------------------------

/// Requests slower than this end-to-end are promoted to `log::warn!`
/// with their full span breakdown. Default 1 s.
static SLOW_REQUEST_MICROS: AtomicU64 = AtomicU64::new(1_000_000);
/// Per-mille of *untraced* server requests stamped with a generated
/// trace id (so their chains group in `/trace`). Default 0.
static SERVER_SAMPLE_PER_MILLE: AtomicU64 = AtomicU64::new(0);
/// Per-mille of client pipeline submissions stamped with a fresh trace.
/// Default 0 — tracing-off clients pay one relaxed load per submit.
static CLIENT_SAMPLE_PER_MILLE: AtomicU64 = AtomicU64::new(0);

pub fn slow_request_threshold() -> Duration {
    Duration::from_micros(SLOW_REQUEST_MICROS.load(Ordering::Relaxed))
}

pub fn set_slow_request_micros(micros: u64) {
    SLOW_REQUEST_MICROS.store(micros.max(1), Ordering::Relaxed);
}

pub fn server_sample_per_mille() -> u64 {
    SERVER_SAMPLE_PER_MILLE.load(Ordering::Relaxed)
}

pub fn set_server_sample_per_mille(per_mille: u64) {
    SERVER_SAMPLE_PER_MILLE.store(per_mille.min(1000), Ordering::Relaxed);
}

pub fn set_client_sampling(per_mille: u64) {
    CLIENT_SAMPLE_PER_MILLE.store(per_mille.min(1000), Ordering::Relaxed);
}

/// Whether this client submission should mint a [`TraceContext`].
/// Deterministic rotor rather than an RNG: exactly `per_mille` of every
/// 1000 consecutive submissions are sampled.
pub fn should_sample_client() -> bool {
    let pm = CLIENT_SAMPLE_PER_MILLE.load(Ordering::Relaxed);
    if pm == 0 {
        return false;
    }
    static ROTOR: AtomicU64 = AtomicU64::new(0);
    ROTOR.fetch_add(1, Ordering::Relaxed) % 1000 < pm
}

/// Server-side counterpart for untraced requests.
pub fn should_sample_server() -> bool {
    let pm = SERVER_SAMPLE_PER_MILLE.load(Ordering::Relaxed);
    if pm == 0 {
        return false;
    }
    static ROTOR: AtomicU64 = AtomicU64::new(0);
    ROTOR.fetch_add(1, Ordering::Relaxed) % 1000 < pm
}

// ---------------------------------------------------------------------
// thread-local stage accumulators (fed from inside core::table)
// ---------------------------------------------------------------------

thread_local! {
    static LOCK_WAIT: std::cell::Cell<u64> = const { std::cell::Cell::new(0) };
    static JOURNAL_WAIT: std::cell::Cell<u64> = const { std::cell::Cell::new(0) };
}

/// Credit contended shard-lock wait to the current thread's accumulator
/// (called by `core::table` under no locks).
pub fn add_lock_wait(d: Duration) {
    LOCK_WAIT.with(|c| c.set(c.get().saturating_add(d.as_nanos() as u64)));
}

/// Credit durability-sink time to the current thread's accumulator.
pub fn add_journal_wait(d: Duration) {
    JOURNAL_WAIT.with(|c| c.set(c.get().saturating_add(d.as_nanos() as u64)));
}

/// Drain the lock-wait accumulator (serving code calls this once per op;
/// the table fills it while the op runs on the same thread).
pub fn take_lock_wait() -> Duration {
    Duration::from_nanos(LOCK_WAIT.with(|c| c.replace(0)))
}

/// Drain the journal-wait accumulator.
pub fn take_journal_wait() -> Duration {
    Duration::from_nanos(JOURNAL_WAIT.with(|c| c.replace(0)))
}

// ---------------------------------------------------------------------
// per-request span accumulator
// ---------------------------------------------------------------------

/// Stage times accumulated while one request moves through a service
/// model. Carried inside the event core's `ParkedOp` across parks, and
/// on the threaded model's stack across gate slices; finished exactly
/// once when the reply is built.
#[derive(Debug)]
pub struct ReqSpans {
    pub trace: Option<TraceContext>,
    pub gate: Duration,
    pub lock: Duration,
    pub execute: Duration,
    pub journal: Duration,
    /// Set while the op is parked (corridor or checkpoint gate); the
    /// resume path folds `now - parked_since` into `gate`.
    pub parked_since: Option<Instant>,
}

impl ReqSpans {
    pub fn new(trace: Option<TraceContext>) -> ReqSpans {
        ReqSpans {
            trace,
            gate: Duration::ZERO,
            lock: Duration::ZERO,
            execute: Duration::ZERO,
            journal: Duration::ZERO,
            parked_since: None,
        }
    }

    /// Mark the op parked (idempotent: only the first park in a chain of
    /// immediate re-attempts stamps the clock).
    pub fn parked(&mut self) {
        if self.parked_since.is_none() {
            self.parked_since = Some(Instant::now());
        }
    }

    /// Fold a finished park into the gate stage.
    pub fn resumed(&mut self) {
        if let Some(since) = self.parked_since.take() {
            self.gate += since.elapsed();
        }
    }

    /// Account one table-op attempt: `total` is the wall time of the
    /// call; the thread-local lock/journal accumulators (filled by
    /// `core::table` during the call) are drained and subtracted, the
    /// remainder is execute time.
    pub fn op_attempt(&mut self, total: Duration) {
        let lock = take_lock_wait();
        let journal = take_journal_wait();
        self.lock += lock;
        self.journal += journal;
        self.execute += total.saturating_sub(lock).saturating_sub(journal);
    }

    /// Finish the request: write the stage chain into the flight
    /// recorder, promote slow requests to `log::warn!`, and hand the
    /// stage durations back for the caller's histogram map. `started`
    /// is the request arrival time, `table` the op's table name.
    pub fn finish(mut self, table: &str, started: Instant) -> [(Stage, Duration); 4] {
        self.resumed();
        let total = started.elapsed();
        let rec = recorder();
        let cat = rec.intern(table);
        // Lay the stages out consecutively from the arrival time so the
        // Chrome trace shows a contiguous chain per request.
        let mut at = started;
        for (stage, dur) in [
            (Stage::Gate, self.gate),
            (Stage::Lock, self.lock),
            (Stage::Execute, self.execute),
            (Stage::Journal, self.journal),
        ] {
            if !dur.is_zero() {
                rec.record_at(self.trace, stage, cat, at, dur);
            }
            at += dur;
        }
        if total >= slow_request_threshold() {
            let ids = self
                .trace
                .map(|t| format!(" trace={:016x}", t.trace_id))
                .unwrap_or_default();
            log::warn!(
                "slow request table={table:?}{ids} total={total:?} \
                 gate={:?} lock={:?} execute={:?} journal={:?}",
                self.gate,
                self.lock,
                self.execute,
                self.journal,
            );
        }
        [
            (Stage::Gate, self.gate),
            (Stage::Lock, self.lock),
            (Stage::Execute, self.execute),
            (Stage::Journal, self.journal),
        ]
    }
}

// ---------------------------------------------------------------------
// the flight recorder
// ---------------------------------------------------------------------

/// Lanes in the span ring. Writer threads hash onto a lane, so up to
/// this many threads record without sharing a head counter.
const N_LANES: usize = 16;
/// Spans per lane; the ring holds `N_LANES * LANE_SLOTS` spans total and
/// overwrites the oldest per lane (a flight recorder, not a log).
const LANE_SLOTS: usize = 1024;

/// One recorded span, as read back out of the ring.
#[derive(Clone, Debug)]
pub struct SpanRecord {
    pub trace_id: u64,
    pub span_id: u64,
    pub stage: Stage,
    /// Interned category (table name or `_server`/`_client`).
    pub cat: String,
    /// Microseconds since the recorder epoch.
    pub start_us: u64,
    pub dur_us: u64,
    /// Lane the writer recorded on (rendered as the Chrome `tid`).
    pub lane: usize,
}

/// One ring slot: a seqlock word plus five payload words. Writers bump
/// `seq` to odd, store the payload, bump to even; readers accept a slot
/// only if `seq` is even and unchanged across the payload reads.
struct Slot {
    seq: AtomicU64,
    trace_id: AtomicU64,
    span_id: AtomicU64,
    /// `stage | cat << 8` — stage in the low byte, interned category
    /// id in the next 16 bits.
    packed: AtomicU64,
    start_us: AtomicU64,
    dur_us: AtomicU64,
}

impl Slot {
    const fn new() -> Slot {
        Slot {
            seq: AtomicU64::new(0),
            trace_id: AtomicU64::new(0),
            span_id: AtomicU64::new(0),
            packed: AtomicU64::new(0),
            start_us: AtomicU64::new(0),
            dur_us: AtomicU64::new(0),
        }
    }
}

struct Lane {
    head: AtomicUsize,
    slots: Vec<Slot>,
}

/// The process-global flight recorder (see module docs for why global).
pub struct Recorder {
    epoch: Instant,
    lanes: Vec<Lane>,
    /// Interned category names; span slots carry a `u16` id instead of a
    /// string so the write path stays allocation-free after the first
    /// record per table.
    cats: Mutex<Vec<String>>,
}

/// Access the global recorder, creating it on first use.
pub fn recorder() -> &'static Recorder {
    static RECORDER: OnceLock<Recorder> = OnceLock::new();
    RECORDER.get_or_init(Recorder::new)
}

thread_local! {
    static MY_LANE: std::cell::Cell<usize> = const { std::cell::Cell::new(usize::MAX) };
}

impl Recorder {
    fn new() -> Recorder {
        Recorder {
            epoch: Instant::now(),
            lanes: (0..N_LANES)
                .map(|_| Lane {
                    head: AtomicUsize::new(0),
                    slots: (0..LANE_SLOTS).map(|_| Slot::new()).collect(),
                })
                .collect(),
            cats: Mutex::new(Vec::new()),
        }
    }

    /// Nanoseconds since the recorder epoch — a monotonic stamp that fits
    /// in an atomic, for cross-thread timing (the event core's ready-queue
    /// wait).
    pub fn nanos_since_epoch(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }

    /// Intern a category name (table name or a `_server`/`_client`
    /// pseudo-table) to the `u16` id the span slots store.
    pub fn intern(&self, name: &str) -> u16 {
        let mut cats = self.cats.lock().unwrap();
        if let Some(i) = cats.iter().position(|c| c == name) {
            return i as u16;
        }
        // Cap the namespace defensively; id 0xFFFF renders as "_other".
        if cats.len() >= u16::MAX as usize {
            return u16::MAX;
        }
        cats.push(name.to_string());
        (cats.len() - 1) as u16
    }

    fn resolve(&self, id: u16) -> String {
        self.cats
            .lock()
            .unwrap()
            .get(id as usize)
            .cloned()
            .unwrap_or_else(|| "_other".into())
    }

    fn lane_for_thread(&self) -> usize {
        MY_LANE.with(|c| {
            let v = c.get();
            if v != usize::MAX {
                return v;
            }
            static NEXT: AtomicUsize = AtomicUsize::new(0);
            let lane = NEXT.fetch_add(1, Ordering::Relaxed) % N_LANES;
            c.set(lane);
            lane
        })
    }

    /// Record one span with an explicit start instant.
    pub fn record_at(
        &self,
        trace: Option<TraceContext>,
        stage: Stage,
        cat: u16,
        start: Instant,
        dur: Duration,
    ) {
        let start_us = start.saturating_duration_since(self.epoch).as_micros() as u64;
        let lane = &self.lanes[self.lane_for_thread()];
        let idx = lane.head.fetch_add(1, Ordering::Relaxed) % LANE_SLOTS;
        let slot = &lane.slots[idx];
        // Seqlock write: odd while mutating, even when done. A reader
        // racing with us sees an odd or changed seq and discards.
        let seq = slot.seq.load(Ordering::Relaxed) | 1;
        slot.seq.store(seq, Ordering::Release);
        let (trace_id, span_id) = trace.map(|t| (t.trace_id, t.span_id)).unwrap_or((0, 0));
        slot.trace_id.store(trace_id, Ordering::Relaxed);
        slot.span_id.store(span_id, Ordering::Relaxed);
        slot.packed
            .store(stage as u8 as u64 | (cat as u64) << 8, Ordering::Relaxed);
        slot.start_us.store(start_us, Ordering::Relaxed);
        slot.dur_us.store(dur.as_micros() as u64, Ordering::Relaxed);
        slot.seq.store(seq + 1, Ordering::Release);
    }

    /// Convenience: record a span measured up to now.
    pub fn record(
        &self,
        trace: Option<TraceContext>,
        stage: Stage,
        cat: u16,
        start: Instant,
    ) {
        self.record_at(trace, stage, cat, start, start.elapsed());
    }

    /// Merge-on-read snapshot of every valid slot, sorted by start time.
    pub fn snapshot(&self) -> Vec<SpanRecord> {
        let mut out = Vec::new();
        for (li, lane) in self.lanes.iter().enumerate() {
            for slot in &lane.slots {
                let s1 = slot.seq.load(Ordering::Acquire);
                if s1 == 0 || s1 & 1 == 1 {
                    continue; // never written, or write in progress
                }
                let trace_id = slot.trace_id.load(Ordering::Relaxed);
                let span_id = slot.span_id.load(Ordering::Relaxed);
                let packed = slot.packed.load(Ordering::Relaxed);
                let start_us = slot.start_us.load(Ordering::Relaxed);
                let dur_us = slot.dur_us.load(Ordering::Relaxed);
                if slot.seq.load(Ordering::Acquire) != s1 {
                    continue; // torn: overwritten while reading
                }
                let Some(stage) = Stage::from_u8((packed & 0xFF) as u8) else {
                    continue;
                };
                out.push(SpanRecord {
                    trace_id,
                    span_id,
                    stage,
                    cat: self.resolve((packed >> 8 & 0xFFFF) as u16),
                    start_us,
                    dur_us,
                    lane: li,
                });
            }
        }
        out.sort_by_key(|s| s.start_us);
        out
    }

    /// Spans recorded for one trace id (test/debug helper).
    pub fn spans_for(&self, trace_id: u64) -> Vec<SpanRecord> {
        self.snapshot()
            .into_iter()
            .filter(|s| s.trace_id == trace_id)
            .collect()
    }

    /// Render the ring as Chrome trace-event JSON (the `chrome://tracing`
    /// / Perfetto "JSON Array" flavour): one complete-event (`ph:"X"`)
    /// per span, lanes mapped to tids.
    pub fn render_chrome_json(&self) -> String {
        let spans = self.snapshot();
        let mut out = String::with_capacity(64 + spans.len() * 128);
        out.push_str("{\"traceEvents\":[");
        for (i, s) in spans.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "{{\"name\":\"{}\",\"cat\":\"{}\",\"ph\":\"X\",\"ts\":{},\"dur\":{},\
                 \"pid\":1,\"tid\":{},\"args\":{{\"trace_id\":\"{:016x}\",\"span_id\":\"{:016x}\"}}}}",
                s.stage.name(),
                escape_json(&s.cat),
                s.start_us,
                s.dur_us,
                s.lane,
                s.trace_id,
                s.span_id,
            ));
        }
        out.push_str("],\"displayTimeUnit\":\"ms\"}");
        out
    }
}

/// Minimal JSON string escaping for category names (tables are
/// CLI-supplied and may contain anything).
fn escape_json(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_context_ids_are_distinct() {
        let a = TraceContext::generate();
        let b = TraceContext::generate();
        assert_ne!(a.trace_id, b.trace_id);
        assert_ne!(a.span_id, b.span_id);
        let c = a.child();
        assert_eq!(c.trace_id, a.trace_id);
        assert_ne!(c.span_id, a.span_id);
        assert!(a.sampled && c.sampled);
    }

    #[test]
    fn recorder_roundtrips_spans_by_trace_id() {
        let rec = recorder();
        let ctx = TraceContext::generate();
        let cat = rec.intern("trace_test_table");
        let start = Instant::now();
        rec.record_at(Some(ctx), Stage::Execute, cat, start, Duration::from_micros(120));
        rec.record_at(Some(ctx), Stage::Gate, cat, start, Duration::from_micros(40));
        let spans = rec.spans_for(ctx.trace_id);
        assert_eq!(spans.len(), 2);
        assert!(spans.iter().any(|s| s.stage == Stage::Execute && s.dur_us == 120));
        assert!(spans.iter().any(|s| s.stage == Stage::Gate && s.dur_us == 40));
        assert!(spans.iter().all(|s| s.cat == "trace_test_table"));
    }

    #[test]
    fn intern_is_stable_and_reused() {
        let rec = recorder();
        let a = rec.intern("intern_test_a");
        let b = rec.intern("intern_test_b");
        assert_ne!(a, b);
        assert_eq!(rec.intern("intern_test_a"), a);
        assert_eq!(rec.resolve(a), "intern_test_a");
    }

    #[test]
    fn chrome_json_renders_all_fields() {
        let rec = recorder();
        let ctx = TraceContext::generate();
        let cat = rec.intern("json_test");
        rec.record_at(Some(ctx), Stage::Flush, cat, Instant::now(), Duration::from_micros(7));
        let json = rec.render_chrome_json();
        assert!(json.starts_with("{\"traceEvents\":["));
        assert!(json.ends_with("]}") || json.ends_with("\"}"), "{}", &json[json.len() - 16..]);
        assert!(json.contains("\"name\":\"flush\""));
        assert!(json.contains("\"cat\":\"json_test\""));
        assert!(json.contains(&format!("{:016x}", ctx.trace_id)));
        assert!(json.contains("\"ph\":\"X\""));
    }

    #[test]
    fn ring_overwrites_oldest_per_lane() {
        // Fill one thread's lane twice over: the snapshot keeps at most
        // LANE_SLOTS spans for this lane and the newest survive.
        let rec = recorder();
        let ctx = TraceContext::generate();
        let cat = rec.intern("wrap_test");
        let start = Instant::now();
        for i in 0..(LANE_SLOTS * 2) {
            rec.record_at(
                Some(TraceContext { span_id: i as u64 + 1, ..ctx }),
                Stage::Execute,
                cat,
                start,
                Duration::from_micros(1),
            );
        }
        let spans = rec.spans_for(ctx.trace_id);
        assert!(spans.len() <= LANE_SLOTS);
        // The newest span id must have survived the wrap.
        assert!(spans.iter().any(|s| s.span_id == (LANE_SLOTS * 2) as u64));
    }

    #[test]
    fn req_spans_accumulates_and_finishes() {
        let started = Instant::now();
        let mut spans = ReqSpans::new(Some(TraceContext::generate()));
        add_lock_wait(Duration::from_micros(50));
        add_journal_wait(Duration::from_micros(30));
        spans.op_attempt(Duration::from_micros(200));
        assert_eq!(spans.lock, Duration::from_micros(50));
        assert_eq!(spans.journal, Duration::from_micros(30));
        assert_eq!(spans.execute, Duration::from_micros(120));
        spans.parked();
        std::thread::sleep(Duration::from_millis(2));
        spans.resumed();
        assert!(spans.gate >= Duration::from_millis(2));
        let trace_id = spans.trace.unwrap().trace_id;
        let out = spans.finish("finish_test", started);
        assert_eq!(out.len(), 4);
        let recorded = recorder().spans_for(trace_id);
        assert!(recorded.iter().any(|s| s.stage == Stage::Gate));
        assert!(recorded.iter().any(|s| s.stage == Stage::Execute));
    }

    #[test]
    fn tls_accumulators_drain_once() {
        let _ = take_lock_wait();
        add_lock_wait(Duration::from_micros(9));
        assert_eq!(take_lock_wait(), Duration::from_micros(9));
        assert_eq!(take_lock_wait(), Duration::ZERO);
    }

    #[test]
    fn knobs_clamp_and_roundtrip() {
        let old = SLOW_REQUEST_MICROS.load(Ordering::Relaxed);
        set_slow_request_micros(250_000);
        assert_eq!(slow_request_threshold(), Duration::from_micros(250_000));
        SLOW_REQUEST_MICROS.store(old, Ordering::Relaxed);
        set_server_sample_per_mille(5000);
        assert_eq!(server_sample_per_mille(), 1000);
        set_server_sample_per_mille(0);
    }

    #[test]
    fn client_sampling_rotor_honors_rate() {
        set_client_sampling(1000);
        assert!(should_sample_client());
        set_client_sampling(0);
        assert!(!should_sample_client());
    }

    #[test]
    fn escape_json_handles_specials() {
        assert_eq!(escape_json("a\"b\\c\n"), "a\\\"b\\\\c\\u000a");
    }

    #[test]
    fn torn_or_unwritten_slots_are_skipped() {
        // A slot left odd (writer "in progress") must not surface.
        let rec = recorder();
        let lane = &rec.lanes[0];
        let idx = lane.head.fetch_add(1, Ordering::Relaxed) % LANE_SLOTS;
        lane.slots[idx].seq.store(3, Ordering::Release);
        lane.slots[idx].trace_id.store(0xDEAD_0001, Ordering::Relaxed);
        assert!(rec.spans_for(0xDEAD_0001).is_empty());
        // Finishing the write makes it visible.
        lane.slots[idx]
            .packed
            .store(Stage::Execute as u8 as u64, Ordering::Relaxed);
        lane.slots[idx].seq.store(4, Ordering::Release);
        assert_eq!(rec.spans_for(0xDEAD_0001).len(), 1);
    }
}
