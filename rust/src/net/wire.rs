//! Wire protocol: the gRPC replacement (see DESIGN.md §2).
//!
//! On byte-stream transports (TCP), frames are
//! `[u32 length][u8 message-tag][payload]`. On the in-process transport,
//! whole [`Message`] values move through channels and this codec is never
//! invoked — which is why chunk payloads are carried as `Arc<Chunk>`
//! handles: the encoder serializes straight from the shared handle (no
//! payload clone on the TCP hot path) and the in-process path shares the
//! handle itself (no serialization at all).
//!
//! The protocol keeps the properties of Reverb's gRPC service that matter
//! for behaviour and benchmarks: long-lived insert/sample streams, chunks
//! transmitted separately from (and before) the items that reference them,
//! pipelined acknowledgements for client-side flow control, and chunk
//! deduplication within a sample response.

use crate::core::chunk::Chunk;
use crate::core::item::TrajectoryColumn;
use crate::core::rate_limiter::RateLimiterConfig;
use crate::core::selector::SelectorConfig;
use crate::core::table::{TableConfig, TableInfo};
use crate::error::{Error, Result};
use crate::io::*;
use crate::net::trace::TraceContext;
use std::io::{Read, Write};
use std::sync::Arc;

/// Maximum frame payload (1 GiB) — guards against corrupt length prefixes.
pub const MAX_FRAME_LEN: usize = 1 << 30;

/// Metadata of an item on the wire (both directions).
///
/// Two frame versions exist (DESIGN.md §9): v1 carries the flat
/// `(chunk_keys, offset, length)` span only; v2 appends an optional
/// per-column slice list (serialized by
/// [`TrajectoryColumn::encode_list`], the codec the checkpoint format
/// shares). The encoder emits a v1 frame whenever `columns` is `None`, so
/// legacy traffic keeps the original byte layout and the v1 decoder stays
/// exercised.
#[derive(Clone, Debug, PartialEq)]
pub struct WireItem {
    pub key: u64,
    pub table: String,
    pub priority: f64,
    /// Referenced chunks. For trajectory items: the deduplicated union of
    /// every column's slice keys, in first-use order.
    pub chunk_keys: Vec<u64>,
    pub offset: u64,
    pub length: u64,
    pub times_sampled: u32,
    /// Per-column slices (`Some` = trajectory item, v2 frame). Shared with
    /// the table's item on the server sampling path, so building a
    /// response copies a pointer rather than the column metadata.
    pub columns: Option<Arc<Vec<TrajectoryColumn>>>,
}

/// One sampled item entry in a [`Message::SampleData`] response.
#[derive(Clone, Debug, PartialEq)]
pub struct WireSampleInfo {
    pub item: WireItem,
    pub probability: f64,
    pub table_size: u64,
}

/// One priority-mutation op inside a [`Message::PriorityUpdateBatch`]:
/// the payload of a `MutatePriorities` without its request id (the batch
/// carries one id and the reply reports per-op outcomes positionally).
#[derive(Clone, Debug, PartialEq)]
pub struct PriorityUpdateOp {
    pub table: String,
    pub updates: Vec<(u64, f64)>,
    pub deletes: Vec<u64>,
}

/// Per-op outcome inside a [`Message::BatchReply`], in op order. A batch
/// is applied op by op; one failing op does not abort the ops after it,
/// so every slot reports independently.
#[derive(Clone, Debug, PartialEq)]
pub enum BatchResult {
    /// The op committed; `detail` matches what a standalone `Ack` carries.
    Ok { detail: String },
    /// The op failed; `code` is a [`code`] constant.
    Err { code: u8, message: String },
}

impl BatchResult {
    /// Collapse to a client-side `Result`, rebuilding the error by code.
    pub fn into_result(self) -> Result<String> {
        match self {
            BatchResult::Ok { detail } => Ok(detail),
            BatchResult::Err { code, message } => Err(error_from_code(code, message)),
        }
    }

    /// Build from a server-side op outcome.
    pub fn from_result(r: std::result::Result<String, &Error>) -> BatchResult {
        match r {
            Ok(detail) => BatchResult::Ok { detail },
            Err(e) => BatchResult::Err {
                code: error_code(e),
                message: e.to_string(),
            },
        }
    }
}

/// Everything that travels between client and server.
///
/// `Clone` is cheap by construction: chunk payloads are `Arc<Chunk>`
/// handles, so cloning a frame copies pointers and small metadata. The
/// pool fabric ([`crate::client::fabric`]) relies on this to retain a
/// re-sendable copy of routed frames for failover replay.
#[derive(Clone, Debug)]
pub enum Message {
    // ---- client → server ----
    /// Stream chunks ahead of the items that reference them. No reply.
    InsertChunks { chunks: Vec<Arc<Chunk>> },
    /// Create an item referencing previously-streamed chunks. Server
    /// replies `Ack { id }` (or `Err`) once the insert commits, enabling
    /// windowed pipelining.
    CreateItem { id: u64, item: WireItem, timeout_ms: u64 },
    /// Request a batch of samples. Server replies `SampleData` or `Err`
    /// (notably `RateLimiterTimeout` → client end-of-sequence, §3.9).
    SampleRequest {
        id: u64,
        table: String,
        num_samples: u32,
        timeout_ms: u64,
    },
    /// Priority updates + deletions (client `mutate_priorities`). Ack'd.
    MutatePriorities {
        id: u64,
        table: String,
        updates: Vec<(u64, f64)>,
        deletes: Vec<u64>,
    },
    /// Reset a table. Ack'd.
    Reset { id: u64, table: String },
    /// Request server/table info. Replied with `Info`.
    InfoRequest { id: u64 },
    /// Trigger a checkpoint (§3.7). Ack'd with the checkpoint path echoed.
    Checkpoint { id: u64 },
    /// Admin control plane (DESIGN.md §12): re-tune a live table/server.
    /// Each field is independently optional; `min_diff`/`max_diff` must be
    /// given together (the corridor is validated as a pair). Ack'd with an
    /// audit summary of what changed, or `Err` if validation rejects the
    /// request — in which case *none* of it was applied.
    AdminReconfig {
        id: u64,
        table: String,
        max_size: Option<u64>,
        min_diff: Option<f64>,
        max_diff: Option<f64>,
        /// Server-wide periodic-checkpoint interval; `table` is ignored
        /// for this field.
        checkpoint_interval_ms: Option<u64>,
        /// Server-wide slow-request threshold (µs) for span promotion to
        /// `log::warn!` (DESIGN.md §15); `table` is ignored.
        slow_request_micros: Option<u64>,
        /// Server-wide per-mille of untraced requests stamped with a
        /// generated trace id; `table` is ignored.
        trace_sample_per_mille: Option<u64>,
    },
    /// Subscribe to `TableInfo` deltas for one table (DESIGN.md §12). The
    /// server replies immediately with a `WatchUpdate` snapshot, then
    /// pushes a coalesced `WatchUpdate` after each mutation batch. `id`
    /// names the subscription: every update echoes it, and `WatchCancel`
    /// with the same id tears the subscription down.
    WatchRequest { id: u64, table: String },
    /// Cancel the watch subscription `id`. Ack'd.
    WatchCancel { id: u64 },
    /// Wire v3 (DESIGN.md §13): N `CreateItem` ops in one frame, applied
    /// in order, answered by one [`Message::BatchReply`] with a per-op
    /// outcome in each slot — N inserts cost one syscall each way. Items
    /// may target different tables; each op fails independently. Batches
    /// larger than [`MAX_BATCH_OPS`] are rejected with a per-frame `Err`
    /// (the connection stays usable).
    CreateItemBatch {
        id: u64,
        items: Vec<WireItem>,
        timeout_ms: u64,
        /// Optional span context (DESIGN.md §15), carried behind the
        /// envelope's trace flag bit; `None` keeps the frame byte-identical
        /// to pre-tracing v3.
        trace: Option<TraceContext>,
    },
    /// Wire v3: N priority-mutation ops in one frame, one `BatchReply`.
    /// Each op is a `MutatePriorities` payload; keys inside one op are
    /// grouped per shard under one lock acquisition by the table.
    PriorityUpdateBatch {
        id: u64,
        ops: Vec<PriorityUpdateOp>,
        /// Optional span context (see [`Message::CreateItemBatch`]).
        trace: Option<TraceContext>,
    },
    /// Lightweight liveness probe (replay fabric health checks, DESIGN.md
    /// §14). The server echoes `nonce` back in a [`Message::Pong`] without
    /// touching any table — a pure service-loop round-trip, so probe
    /// latency measures dispatch health rather than data-plane load.
    Ping { id: u64, nonce: u64 },

    // ---- server → client ----
    /// Positive acknowledgement of the request with matching `id`.
    Ack { id: u64, detail: String },
    /// Request failed.
    Err { id: u64, code: u8, message: String },
    /// Sample response: deduplicated chunks + item metadata.
    SampleData {
        id: u64,
        infos: Vec<WireSampleInfo>,
        chunks: Vec<Arc<Chunk>>,
    },
    /// Server info response.
    Info { id: u64, tables: Vec<(String, TableInfo)> },
    /// One pushed delta on watch subscription `id` (also the immediate
    /// snapshot reply to `WatchRequest`). Updates are coalesced: a burst
    /// of mutations between two service rounds yields one frame carrying
    /// the latest state — latest-wins is the backpressure policy.
    WatchUpdate {
        id: u64,
        table: String,
        info: TableInfo,
    },
    /// Wire v3 reply to a batch frame: one [`BatchResult`] per op, in op
    /// order, under the batch's single request id.
    BatchReply {
        id: u64,
        results: Vec<BatchResult>,
        /// The request's span context echoed back, so a pool fabric can
        /// keep the trace attached across its positional reply merge.
        trace: Option<TraceContext>,
    },
    /// Reply to [`Message::Ping`], echoing its `nonce`.
    Pong { id: u64, nonce: u64 },
}

/// Error codes carried by [`Message::Err`].
pub mod code {
    pub const GENERIC: u8 = 0;
    pub const NOT_FOUND: u8 = 1;
    pub const TIMEOUT: u8 = 2;
    pub const CANCELLED: u8 = 3;
    pub const INVALID: u8 = 4;
}

/// Map a server-side error to a wire code.
pub fn error_code(e: &Error) -> u8 {
    match e {
        Error::TableNotFound(_) | Error::ItemNotFound(_) | Error::ChunkNotFound(_) => {
            code::NOT_FOUND
        }
        Error::RateLimiterTimeout(_) => code::TIMEOUT,
        Error::Cancelled(_) => code::CANCELLED,
        Error::InvalidArgument(_) | Error::SignatureMismatch(_) => code::INVALID,
        _ => code::GENERIC,
    }
}

/// Reconstruct a client-side error from a wire code.
pub fn error_from_code(code_: u8, message: String) -> Error {
    match code_ {
        code::TIMEOUT => Error::RateLimiterTimeout(std::time::Duration::ZERO),
        code::CANCELLED => Error::Cancelled(message),
        code::NOT_FOUND => Error::TableNotFound(message),
        code::INVALID => Error::InvalidArgument(message),
        _ => Error::Decode(message),
    }
}

const TAG_INSERT_CHUNKS: u8 = 1;
const TAG_CREATE_ITEM: u8 = 2;
const TAG_SAMPLE_REQUEST: u8 = 3;
const TAG_MUTATE: u8 = 4;
const TAG_RESET: u8 = 5;
const TAG_INFO_REQUEST: u8 = 6;
const TAG_CHECKPOINT: u8 = 7;
/// v2 of `CreateItem`: the item carries per-column trajectory slices.
const TAG_CREATE_ITEM_V2: u8 = 8;
const TAG_ADMIN_RECONFIG: u8 = 9;
const TAG_WATCH_REQUEST: u8 = 10;
const TAG_WATCH_CANCEL: u8 = 11;
const TAG_ACK: u8 = 128;
const TAG_ERR: u8 = 129;
const TAG_SAMPLE_DATA: u8 = 130;
const TAG_INFO: u8 = 131;
/// v2 of `SampleData`: at least one item carries trajectory slices.
const TAG_SAMPLE_DATA_V2: u8 = 132;
const TAG_WATCH_UPDATE: u8 = 133;
/// v3 batched ops (bodies start with the versioned envelope).
const TAG_CREATE_ITEM_BATCH: u8 = 12;
const TAG_PRIORITY_UPDATE_BATCH: u8 = 13;
const TAG_BATCH_REPLY: u8 = 134;
/// Fabric liveness probe and its echo (DESIGN.md §14).
const TAG_PING: u8 = 14;
const TAG_PONG: u8 = 135;

/// Server-side cap on ops per batch frame. Larger batches are refused
/// with a clean per-frame `Err` (code `INVALID`) rather than a decode
/// failure, so a misconfigured client keeps a usable connection. The
/// decode-level cap (1 << 20) only guards against corrupt length fields.
pub const MAX_BATCH_OPS: usize = 4096;

/// Versioned envelope leading every v3 body: `[magic "Rv"][version][flags]`.
///
/// Earlier frame revisions were told apart by tag archaeology
/// (`CREATE_ITEM` vs `CREATE_ITEM_V2`, checkpoint magics). From v3 on, a
/// new frame family declares its version explicitly: a decoder that sees
/// version 4 reports "unsupported wire version 4" instead of a baffling
/// field-level decode error, and flags give v3 room to grow without a new
/// tag. v1/v2 frame bodies are byte-for-byte unchanged.
const ENVELOPE_MAGIC: [u8; 2] = *b"Rv";
/// Wire version stamped into (and required from) the envelope.
pub const WIRE_VERSION: u8 = 3;
/// Envelope flag bit: a 17-byte trace-context extension
/// (`[u64 trace_id][u64 span_id][u8 sampled]`) follows the flags byte.
/// Frames without a trace keep the flags byte 0 and are byte-for-byte
/// identical to pre-tracing v3 — an untagged peer never sees the bit.
const FLAG_TRACE: u8 = 0x01;

fn put_envelope<W: Write>(w: &mut W, trace: Option<&TraceContext>) -> Result<()> {
    w.write_all(&ENVELOPE_MAGIC)?;
    put_u8(w, WIRE_VERSION)?;
    match trace {
        None => put_u8(w, 0), // flags, reserved
        Some(t) => {
            put_u8(w, FLAG_TRACE)?;
            put_u64(w, t.trace_id)?;
            put_u64(w, t.span_id)?;
            put_u8(w, t.sampled as u8)
        }
    }
}

fn check_envelope<R: Read>(r: &mut R) -> Result<Option<TraceContext>> {
    let mut magic = [0u8; 2];
    r.read_exact(&mut magic)?;
    if magic != ENVELOPE_MAGIC {
        return Err(Error::Decode(format!(
            "bad envelope magic {magic:02x?} (expected {ENVELOPE_MAGIC:02x?})"
        )));
    }
    let version = get_u8(r)?;
    if version != WIRE_VERSION {
        return Err(Error::Decode(format!(
            "unsupported wire version {version} (this build speaks {WIRE_VERSION})"
        )));
    }
    let flags = get_u8(r)?;
    if flags & !FLAG_TRACE != 0 {
        return Err(Error::Decode(format!("unknown envelope flags {flags:#x}")));
    }
    if flags & FLAG_TRACE == 0 {
        return Ok(None);
    }
    let trace_id = get_u64(r)?;
    let span_id = get_u64(r)?;
    let sampled = match get_u8(r)? {
        0 => false,
        1 => true,
        f => return Err(Error::Decode(format!("bad trace sampled flag {f}"))),
    };
    Ok(Some(TraceContext {
        trace_id,
        span_id,
        sampled,
    }))
}

/// Optional-field layout shared by the admin frames: `[u8 present][value]`.
fn put_opt_u64<W: Write>(w: &mut W, v: Option<u64>) -> Result<()> {
    match v {
        Some(x) => {
            put_u8(w, 1)?;
            put_u64(w, x)
        }
        None => put_u8(w, 0),
    }
}

fn get_opt_u64<R: Read>(r: &mut R) -> Result<Option<u64>> {
    match get_u8(r)? {
        0 => Ok(None),
        1 => Ok(Some(get_u64(r)?)),
        f => Err(Error::Decode(format!("bad option flag {f}"))),
    }
}

fn put_opt_f64<W: Write>(w: &mut W, v: Option<f64>) -> Result<()> {
    match v {
        Some(x) => {
            put_u8(w, 1)?;
            put_f64(w, x)
        }
        None => put_u8(w, 0),
    }
}

fn get_opt_f64<R: Read>(r: &mut R) -> Result<Option<f64>> {
    match get_u8(r)? {
        0 => Ok(None),
        1 => Ok(Some(get_f64(r)?)),
        f => Err(Error::Decode(format!("bad option flag {f}"))),
    }
}

/// `TableInfo` layout shared by the `Info` and `WatchUpdate` frames.
fn put_table_info<W: Write>(w: &mut W, info: &TableInfo) -> Result<()> {
    put_u64(w, info.size as u64)?;
    put_u64(w, info.max_size as u64)?;
    put_u64(w, info.inserts)?;
    put_u64(w, info.samples)?;
    put_u64(w, info.rate_limited_inserts)?;
    put_u64(w, info.rate_limited_samples)?;
    put_f64(w, info.diff)?;
    put_f64(w, info.total_weight)?;
    Ok(())
}

fn get_table_info<R: Read>(r: &mut R) -> Result<TableInfo> {
    Ok(TableInfo {
        size: get_u64(r)? as usize,
        max_size: get_u64(r)? as usize,
        inserts: get_u64(r)?,
        samples: get_u64(r)?,
        rate_limited_inserts: get_u64(r)?,
        rate_limited_samples: get_u64(r)?,
        diff: get_f64(r)?,
        total_weight: get_f64(r)?,
    })
}

/// v1 item layout (no columns). Callers route items with columns to
/// [`put_wire_item_v2`]; encoding them here would silently drop the
/// trajectory, so that is a hard error.
fn put_wire_item<W: Write>(w: &mut W, item: &WireItem) -> Result<()> {
    if item.columns.is_some() {
        return Err(Error::InvalidArgument(
            "trajectory item on a v1 frame".into(),
        ));
    }
    put_wire_item_common(w, item)
}

fn put_wire_item_common<W: Write>(w: &mut W, item: &WireItem) -> Result<()> {
    put_u64(w, item.key)?;
    put_string(w, &item.table)?;
    put_f64(w, item.priority)?;
    put_u32(w, item.chunk_keys.len() as u32)?;
    for &k in &item.chunk_keys {
        put_u64(w, k)?;
    }
    put_u64(w, item.offset)?;
    put_u64(w, item.length)?;
    put_u32(w, item.times_sampled)?;
    Ok(())
}

/// v2 item layout: the v1 fields followed by an optional column list.
fn put_wire_item_v2<W: Write>(w: &mut W, item: &WireItem) -> Result<()> {
    put_wire_item_common(w, item)?;
    TrajectoryColumn::encode_list(item.columns.as_deref().map(|v| v.as_slice()), w)
}

fn get_wire_item<R: Read>(r: &mut R) -> Result<WireItem> {
    let key = get_u64(r)?;
    let table = get_string(r)?;
    let priority = get_f64(r)?;
    let n = get_u32(r)? as usize;
    if n > 1 << 20 {
        return Err(Error::Decode(format!("{n} chunk keys exceeds limit")));
    }
    let chunk_keys = (0..n).map(|_| get_u64(r)).collect::<Result<_>>()?;
    Ok(WireItem {
        key,
        table,
        priority,
        chunk_keys,
        offset: get_u64(r)?,
        length: get_u64(r)?,
        times_sampled: get_u32(r)?,
        columns: None,
    })
}

fn get_wire_item_v2<R: Read>(r: &mut R) -> Result<WireItem> {
    let mut item = get_wire_item(r)?;
    item.columns = TrajectoryColumn::decode_list(r)?.map(Arc::new);
    Ok(item)
}

impl Message {
    /// Serialize the message body (without the frame header).
    pub fn encode_body(&self) -> Result<(u8, Vec<u8>)> {
        let mut b = Vec::new();
        let tag = match self {
            Message::InsertChunks { chunks } => {
                put_u32(&mut b, chunks.len() as u32)?;
                for c in chunks {
                    c.encode(&mut b)?;
                }
                TAG_INSERT_CHUNKS
            }
            Message::CreateItem { id, item, timeout_ms } => {
                put_u64(&mut b, *id)?;
                if item.columns.is_some() {
                    put_wire_item_v2(&mut b, item)?;
                    put_u64(&mut b, *timeout_ms)?;
                    TAG_CREATE_ITEM_V2
                } else {
                    put_wire_item(&mut b, item)?;
                    put_u64(&mut b, *timeout_ms)?;
                    TAG_CREATE_ITEM
                }
            }
            Message::SampleRequest {
                id,
                table,
                num_samples,
                timeout_ms,
            } => {
                put_u64(&mut b, *id)?;
                put_string(&mut b, table)?;
                put_u32(&mut b, *num_samples)?;
                put_u64(&mut b, *timeout_ms)?;
                TAG_SAMPLE_REQUEST
            }
            Message::MutatePriorities {
                id,
                table,
                updates,
                deletes,
            } => {
                put_u64(&mut b, *id)?;
                put_string(&mut b, table)?;
                put_u32(&mut b, updates.len() as u32)?;
                for (k, p) in updates {
                    put_u64(&mut b, *k)?;
                    put_f64(&mut b, *p)?;
                }
                put_u32(&mut b, deletes.len() as u32)?;
                for k in deletes {
                    put_u64(&mut b, *k)?;
                }
                TAG_MUTATE
            }
            Message::Reset { id, table } => {
                put_u64(&mut b, *id)?;
                put_string(&mut b, table)?;
                TAG_RESET
            }
            Message::InfoRequest { id } => {
                put_u64(&mut b, *id)?;
                TAG_INFO_REQUEST
            }
            Message::Checkpoint { id } => {
                put_u64(&mut b, *id)?;
                TAG_CHECKPOINT
            }
            Message::AdminReconfig {
                id,
                table,
                max_size,
                min_diff,
                max_diff,
                checkpoint_interval_ms,
                slow_request_micros,
                trace_sample_per_mille,
            } => {
                put_u64(&mut b, *id)?;
                put_string(&mut b, table)?;
                put_opt_u64(&mut b, *max_size)?;
                put_opt_f64(&mut b, *min_diff)?;
                put_opt_f64(&mut b, *max_diff)?;
                put_opt_u64(&mut b, *checkpoint_interval_ms)?;
                put_opt_u64(&mut b, *slow_request_micros)?;
                put_opt_u64(&mut b, *trace_sample_per_mille)?;
                TAG_ADMIN_RECONFIG
            }
            Message::WatchRequest { id, table } => {
                put_u64(&mut b, *id)?;
                put_string(&mut b, table)?;
                TAG_WATCH_REQUEST
            }
            Message::WatchCancel { id } => {
                put_u64(&mut b, *id)?;
                TAG_WATCH_CANCEL
            }
            Message::CreateItemBatch {
                id,
                items,
                timeout_ms,
                trace,
            } => {
                put_envelope(&mut b, trace.as_ref())?;
                put_u64(&mut b, *id)?;
                put_u32(&mut b, items.len() as u32)?;
                for item in items {
                    // The v2 item layout carries flat and trajectory items
                    // alike, so a batch never needs two encodings.
                    put_wire_item_v2(&mut b, item)?;
                }
                put_u64(&mut b, *timeout_ms)?;
                TAG_CREATE_ITEM_BATCH
            }
            Message::PriorityUpdateBatch { id, ops, trace } => {
                put_envelope(&mut b, trace.as_ref())?;
                put_u64(&mut b, *id)?;
                put_u32(&mut b, ops.len() as u32)?;
                for op in ops {
                    put_string(&mut b, &op.table)?;
                    put_u32(&mut b, op.updates.len() as u32)?;
                    for (k, p) in &op.updates {
                        put_u64(&mut b, *k)?;
                        put_f64(&mut b, *p)?;
                    }
                    put_u32(&mut b, op.deletes.len() as u32)?;
                    for k in &op.deletes {
                        put_u64(&mut b, *k)?;
                    }
                }
                TAG_PRIORITY_UPDATE_BATCH
            }
            Message::Ping { id, nonce } => {
                put_u64(&mut b, *id)?;
                put_u64(&mut b, *nonce)?;
                TAG_PING
            }
            Message::Pong { id, nonce } => {
                put_u64(&mut b, *id)?;
                put_u64(&mut b, *nonce)?;
                TAG_PONG
            }
            Message::BatchReply { id, results, trace } => {
                put_envelope(&mut b, trace.as_ref())?;
                put_u64(&mut b, *id)?;
                put_u32(&mut b, results.len() as u32)?;
                for res in results {
                    match res {
                        BatchResult::Ok { detail } => {
                            put_u8(&mut b, 1)?;
                            put_string(&mut b, detail)?;
                        }
                        BatchResult::Err { code, message } => {
                            put_u8(&mut b, 0)?;
                            put_u8(&mut b, *code)?;
                            put_string(&mut b, message)?;
                        }
                    }
                }
                TAG_BATCH_REPLY
            }
            Message::Ack { id, detail } => {
                put_u64(&mut b, *id)?;
                put_string(&mut b, detail)?;
                TAG_ACK
            }
            Message::Err { id, code, message } => {
                put_u64(&mut b, *id)?;
                put_u8(&mut b, *code)?;
                put_string(&mut b, message)?;
                TAG_ERR
            }
            Message::SampleData { id, infos, chunks } => {
                // One trajectory item upgrades the whole frame to v2 (the
                // v2 item layout still carries flat items unchanged).
                let v2 = infos.iter().any(|i| i.item.columns.is_some());
                put_u64(&mut b, *id)?;
                put_u32(&mut b, infos.len() as u32)?;
                for info in infos {
                    if v2 {
                        put_wire_item_v2(&mut b, &info.item)?;
                    } else {
                        put_wire_item(&mut b, &info.item)?;
                    }
                    put_f64(&mut b, info.probability)?;
                    put_u64(&mut b, info.table_size)?;
                }
                put_u32(&mut b, chunks.len() as u32)?;
                for c in chunks {
                    c.encode(&mut b)?;
                }
                if v2 {
                    TAG_SAMPLE_DATA_V2
                } else {
                    TAG_SAMPLE_DATA
                }
            }
            Message::Info { id, tables } => {
                put_u64(&mut b, *id)?;
                put_u32(&mut b, tables.len() as u32)?;
                for (name, info) in tables {
                    put_string(&mut b, name)?;
                    put_table_info(&mut b, info)?;
                }
                TAG_INFO
            }
            Message::WatchUpdate { id, table, info } => {
                put_u64(&mut b, *id)?;
                put_string(&mut b, table)?;
                put_table_info(&mut b, info)?;
                TAG_WATCH_UPDATE
            }
        };
        Ok((tag, b))
    }

    /// Deserialize a message body.
    pub fn decode_body(tag: u8, body: &[u8]) -> Result<Message> {
        let mut r = std::io::Cursor::new(body);
        let msg = match tag {
            TAG_INSERT_CHUNKS => {
                let n = get_u32(&mut r)? as usize;
                if n > 1 << 20 {
                    return Err(Error::Decode(format!("{n} chunks exceeds limit")));
                }
                let chunks = (0..n)
                    .map(|_| Chunk::decode(&mut r).map(Arc::new))
                    .collect::<Result<_>>()?;
                Message::InsertChunks { chunks }
            }
            TAG_CREATE_ITEM => Message::CreateItem {
                id: get_u64(&mut r)?,
                item: get_wire_item(&mut r)?,
                timeout_ms: get_u64(&mut r)?,
            },
            TAG_CREATE_ITEM_V2 => Message::CreateItem {
                id: get_u64(&mut r)?,
                item: get_wire_item_v2(&mut r)?,
                timeout_ms: get_u64(&mut r)?,
            },
            TAG_SAMPLE_REQUEST => Message::SampleRequest {
                id: get_u64(&mut r)?,
                table: get_string(&mut r)?,
                num_samples: get_u32(&mut r)?,
                timeout_ms: get_u64(&mut r)?,
            },
            TAG_MUTATE => {
                let id = get_u64(&mut r)?;
                let table = get_string(&mut r)?;
                let nu = get_u32(&mut r)? as usize;
                if nu > 1 << 24 {
                    return Err(Error::Decode("too many updates".into()));
                }
                let updates = (0..nu)
                    .map(|_| Ok((get_u64(&mut r)?, get_f64(&mut r)?)))
                    .collect::<Result<_>>()?;
                let nd = get_u32(&mut r)? as usize;
                if nd > 1 << 24 {
                    return Err(Error::Decode("too many deletes".into()));
                }
                let deletes = (0..nd).map(|_| get_u64(&mut r)).collect::<Result<_>>()?;
                Message::MutatePriorities {
                    id,
                    table,
                    updates,
                    deletes,
                }
            }
            TAG_RESET => Message::Reset {
                id: get_u64(&mut r)?,
                table: get_string(&mut r)?,
            },
            TAG_INFO_REQUEST => Message::InfoRequest { id: get_u64(&mut r)? },
            TAG_CHECKPOINT => Message::Checkpoint { id: get_u64(&mut r)? },
            TAG_ADMIN_RECONFIG => Message::AdminReconfig {
                id: get_u64(&mut r)?,
                table: get_string(&mut r)?,
                max_size: get_opt_u64(&mut r)?,
                min_diff: get_opt_f64(&mut r)?,
                max_diff: get_opt_f64(&mut r)?,
                checkpoint_interval_ms: get_opt_u64(&mut r)?,
                slow_request_micros: get_opt_u64(&mut r)?,
                trace_sample_per_mille: get_opt_u64(&mut r)?,
            },
            TAG_WATCH_REQUEST => Message::WatchRequest {
                id: get_u64(&mut r)?,
                table: get_string(&mut r)?,
            },
            TAG_WATCH_CANCEL => Message::WatchCancel { id: get_u64(&mut r)? },
            TAG_CREATE_ITEM_BATCH => {
                let trace = check_envelope(&mut r)?;
                let id = get_u64(&mut r)?;
                let n = get_u32(&mut r)? as usize;
                if n > 1 << 20 {
                    return Err(Error::Decode(format!("{n} batch items exceeds limit")));
                }
                let items = (0..n).map(|_| get_wire_item_v2(&mut r)).collect::<Result<_>>()?;
                Message::CreateItemBatch {
                    id,
                    items,
                    timeout_ms: get_u64(&mut r)?,
                    trace,
                }
            }
            TAG_PRIORITY_UPDATE_BATCH => {
                let trace = check_envelope(&mut r)?;
                let id = get_u64(&mut r)?;
                let n = get_u32(&mut r)? as usize;
                if n > 1 << 20 {
                    return Err(Error::Decode(format!("{n} batch ops exceeds limit")));
                }
                let ops = (0..n)
                    .map(|_| {
                        let table = get_string(&mut r)?;
                        let nu = get_u32(&mut r)? as usize;
                        if nu > 1 << 24 {
                            return Err(Error::Decode("too many updates".into()));
                        }
                        let updates = (0..nu)
                            .map(|_| Ok((get_u64(&mut r)?, get_f64(&mut r)?)))
                            .collect::<Result<_>>()?;
                        let nd = get_u32(&mut r)? as usize;
                        if nd > 1 << 24 {
                            return Err(Error::Decode("too many deletes".into()));
                        }
                        let deletes = (0..nd).map(|_| get_u64(&mut r)).collect::<Result<_>>()?;
                        Ok(PriorityUpdateOp {
                            table,
                            updates,
                            deletes,
                        })
                    })
                    .collect::<Result<_>>()?;
                Message::PriorityUpdateBatch { id, ops, trace }
            }
            TAG_PING => Message::Ping {
                id: get_u64(&mut r)?,
                nonce: get_u64(&mut r)?,
            },
            TAG_PONG => Message::Pong {
                id: get_u64(&mut r)?,
                nonce: get_u64(&mut r)?,
            },
            TAG_BATCH_REPLY => {
                let trace = check_envelope(&mut r)?;
                let id = get_u64(&mut r)?;
                let n = get_u32(&mut r)? as usize;
                if n > 1 << 20 {
                    return Err(Error::Decode(format!("{n} batch results exceeds limit")));
                }
                let results = (0..n)
                    .map(|_| match get_u8(&mut r)? {
                        1 => Ok(BatchResult::Ok { detail: get_string(&mut r)? }),
                        0 => Ok(BatchResult::Err {
                            code: get_u8(&mut r)?,
                            message: get_string(&mut r)?,
                        }),
                        f => Err(Error::Decode(format!("bad batch result flag {f}"))),
                    })
                    .collect::<Result<_>>()?;
                Message::BatchReply { id, results, trace }
            }
            TAG_ACK => Message::Ack {
                id: get_u64(&mut r)?,
                detail: get_string(&mut r)?,
            },
            TAG_ERR => Message::Err {
                id: get_u64(&mut r)?,
                code: get_u8(&mut r)?,
                message: get_string(&mut r)?,
            },
            TAG_SAMPLE_DATA | TAG_SAMPLE_DATA_V2 => {
                let id = get_u64(&mut r)?;
                let ni = get_u32(&mut r)? as usize;
                if ni > 1 << 20 {
                    return Err(Error::Decode("too many sample infos".into()));
                }
                let infos = (0..ni)
                    .map(|_| {
                        let item = if tag == TAG_SAMPLE_DATA_V2 {
                            get_wire_item_v2(&mut r)?
                        } else {
                            get_wire_item(&mut r)?
                        };
                        Ok(WireSampleInfo {
                            item,
                            probability: get_f64(&mut r)?,
                            table_size: get_u64(&mut r)?,
                        })
                    })
                    .collect::<Result<_>>()?;
                let nc = get_u32(&mut r)? as usize;
                if nc > 1 << 20 {
                    return Err(Error::Decode("too many chunks".into()));
                }
                let chunks = (0..nc)
                    .map(|_| Chunk::decode(&mut r).map(Arc::new))
                    .collect::<Result<_>>()?;
                Message::SampleData { id, infos, chunks }
            }
            TAG_INFO => {
                let id = get_u64(&mut r)?;
                let n = get_u32(&mut r)? as usize;
                if n > 1 << 16 {
                    return Err(Error::Decode("too many tables".into()));
                }
                let tables = (0..n)
                    .map(|_| Ok((get_string(&mut r)?, get_table_info(&mut r)?)))
                    .collect::<Result<_>>()?;
                Message::Info { id, tables }
            }
            TAG_WATCH_UPDATE => Message::WatchUpdate {
                id: get_u64(&mut r)?,
                table: get_string(&mut r)?,
                info: get_table_info(&mut r)?,
            },
            t => return Err(Error::Decode(format!("unknown message tag {t}"))),
        };
        Ok(msg)
    }

    /// Write a full frame (`[u32 len][u8 tag][body]`).
    ///
    /// Since chunk-bearing variants hold `Arc<Chunk>`, encoding serializes
    /// straight from the shared handle — the server's hot sampling path
    /// (§5.2) never clones chunk payloads to build a response frame.
    pub fn write_frame<W: Write>(&self, w: &mut W) -> Result<()> {
        let (tag, body) = self.encode_body()?;
        put_u32(w, body.len() as u32)?;
        put_u8(w, tag)?;
        w.write_all(&body)?;
        Ok(())
    }

    /// Encode a full frame (`[u32 len][u8 tag][body]`) into one owned
    /// buffer. The TCP transport queues these and flushes the queue with a
    /// single `write_vectored` call, so pipelined small frames
    /// (`InsertChunks` + `CreateItem`, streams of acks) cost one syscall
    /// per flush instead of one per frame — and skip the intermediate
    /// `BufWriter` copy entirely.
    pub fn encode_frame(&self) -> Result<Vec<u8>> {
        let (tag, body) = self.encode_body()?;
        let mut frame = Vec::with_capacity(5 + body.len());
        frame.extend_from_slice(&(body.len() as u32).to_le_bytes());
        frame.push(tag);
        frame.extend_from_slice(&body);
        Ok(frame)
    }

    /// Read one full frame.
    pub fn read_frame<R: Read>(r: &mut R) -> Result<Message> {
        let len = get_u32(r)? as usize;
        if len > MAX_FRAME_LEN {
            return Err(Error::Decode(format!("frame length {len} exceeds limit")));
        }
        let tag = get_u8(r)?;
        let mut body = vec![0u8; len];
        r.read_exact(&mut body)?;
        Message::decode_body(tag, &body)
    }
}

/// Read granularity of [`FrameDecoder`]: one `read(2)` pulls up to this many
/// bytes into the stash (matches the old `BufReader` capacity).
const DECODER_READ_CHUNK: usize = 256 * 1024;

/// Resumable frame decoder: the event-driven service core's read path.
///
/// Unlike [`Message::read_frame`], which issues blocking reads until one
/// frame is complete, the decoder accumulates whatever bytes the socket has
/// *right now* and yields a frame only once its bytes are all present — a
/// `WouldBlock` mid-frame simply suspends the decode until the next
/// readiness event re-drives it. The same decoder also serves the blocking
/// path (a blocking socket never yields `WouldBlock`, so `read_into`
/// completes frames in a loop), which is how the client and the threaded
/// service model route over the identical code.
#[derive(Default)]
pub struct FrameDecoder {
    /// Raw received-but-undecoded bytes. `pos` marks how much of the front
    /// has already been consumed by decoded frames; the tail may hold a
    /// partial frame awaiting more bytes.
    stash: Vec<u8>,
    pos: usize,
    /// Reusable read buffer, zero-initialized once per decoder — `read(2)`
    /// needs initialized memory, and re-zeroing a fresh region per call
    /// would cost a 256 KB memset on every small-frame recv.
    scratch: Vec<u8>,
}

impl FrameDecoder {
    pub fn new() -> Self {
        Self::default()
    }

    /// Decode one frame from the stash if its bytes are fully present.
    fn try_decode(&mut self) -> Result<Option<Message>> {
        let avail = self.stash.len() - self.pos;
        if avail < 5 {
            return Ok(None);
        }
        let len = u32::from_le_bytes(
            self.stash[self.pos..self.pos + 4]
                .try_into()
                .expect("4 bytes"),
        ) as usize;
        if len > MAX_FRAME_LEN {
            return Err(Error::Decode(format!("frame length {len} exceeds limit")));
        }
        if avail < 5 + len {
            return Ok(None);
        }
        let tag = self.stash[self.pos + 4];
        let body = &self.stash[self.pos + 5..self.pos + 5 + len];
        let msg = Message::decode_body(tag, body)?;
        self.pos += 5 + len;
        if self.pos == self.stash.len() {
            self.stash.clear();
            self.pos = 0;
        } else if self.pos >= DECODER_READ_CHUNK {
            // Compact once the dead prefix outgrows a read chunk so the
            // stash does not grow without bound under pipelining.
            self.stash.drain(..self.pos);
            self.pos = 0;
        }
        Ok(Some(msg))
    }

    /// Drive the decoder from `r`: drain buffered frames first, then read.
    ///
    /// - `Ok(Some(msg))` — one complete frame.
    /// - `Ok(None)` — the reader reported `WouldBlock` and no complete
    ///   frame is buffered (re-arm readiness and retry later).
    /// - `Err(Error::Io)` with `UnexpectedEof` — the peer closed (mid-frame
    ///   or at a boundary; callers treat both as a hang-up).
    pub fn read_from<R: Read>(&mut self, r: &mut R) -> Result<Option<Message>> {
        loop {
            if let Some(msg) = self.try_decode()? {
                return Ok(Some(msg));
            }
            if self.scratch.is_empty() {
                self.scratch = vec![0u8; DECODER_READ_CHUNK];
            }
            match r.read(&mut self.scratch) {
                Ok(0) => {
                    return Err(Error::Io(std::io::Error::new(
                        std::io::ErrorKind::UnexpectedEof,
                        "peer closed",
                    )));
                }
                Ok(n) => self.stash.extend_from_slice(&self.scratch[..n]),
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    return Ok(None);
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => return Err(e.into()),
            }
        }
    }

    /// Whether undecoded bytes (a partial frame) are buffered.
    pub fn mid_frame(&self) -> bool {
        self.stash.len() > self.pos
    }
}

/// Encode a table config for config files / diagnostics (used by the
/// server CLI; not part of the client protocol).
pub fn encode_table_config<W: Write>(w: &mut W, cfg: &TableConfig) -> Result<()> {
    put_string(w, &cfg.name)?;
    let (t, p) = cfg.sampler.encode();
    put_u8(w, t)?;
    put_f64(w, p)?;
    let (t, p) = cfg.remover.encode();
    put_u8(w, t)?;
    put_f64(w, p)?;
    put_u64(w, cfg.max_size as u64)?;
    put_u32(w, cfg.max_times_sampled)?;
    let rl = &cfg.rate_limiter;
    put_f64(w, rl.samples_per_insert)?;
    put_u64(w, rl.min_size_to_sample)?;
    put_f64(w, rl.min_diff)?;
    put_f64(w, rl.max_diff)?;
    put_u32(w, cfg.num_shards as u32)?;
    Ok(())
}

/// Inverse of [`encode_table_config`].
pub fn decode_table_config<R: Read>(r: &mut R) -> Result<TableConfig> {
    let name = get_string(r)?;
    let sampler = SelectorConfig::decode(get_u8(r)?, get_f64(r)?)?;
    let remover = SelectorConfig::decode(get_u8(r)?, get_f64(r)?)?;
    let max_size = get_u64(r)? as usize;
    let max_times_sampled = get_u32(r)?;
    let rate_limiter = RateLimiterConfig {
        samples_per_insert: get_f64(r)?,
        min_size_to_sample: get_u64(r)?,
        min_diff: get_f64(r)?,
        max_diff: get_f64(r)?,
    };
    let num_shards = (get_u32(r)? as usize).max(1);
    Ok(TableConfig {
        name,
        sampler,
        remover,
        max_size,
        max_times_sampled,
        rate_limiter,
        signature: None,
        num_shards,
        column_codecs: Vec::new(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::chunk::Compression;
    use crate::core::item::ChunkSlice;
    use crate::core::tensor::Tensor;

    fn mk_chunk(key: u64) -> Arc<Chunk> {
        let steps = vec![
            vec![Tensor::from_f32(&[2], &[1., 2.]).unwrap()],
            vec![Tensor::from_f32(&[2], &[3., 4.]).unwrap()],
        ];
        Arc::new(Chunk::from_steps(key, 0, &steps, Compression::Zstd { level: 1 }).unwrap())
    }

    fn roundtrip(msg: &Message) -> Message {
        let mut buf = Vec::new();
        msg.write_frame(&mut buf).unwrap();
        Message::read_frame(&mut std::io::Cursor::new(buf)).unwrap()
    }

    #[test]
    fn insert_chunks_roundtrip() {
        let msg = Message::InsertChunks {
            chunks: vec![mk_chunk(1), mk_chunk(2)],
        };
        match roundtrip(&msg) {
            Message::InsertChunks { chunks } => {
                assert_eq!(chunks.len(), 2);
                assert_eq!(chunks[0].key, 1);
                assert_eq!(
                    chunks[1].to_steps().unwrap()[1][0].to_f32().unwrap(),
                    vec![3., 4.]
                );
            }
            other => panic!("wrong message {other:?}"),
        }
    }

    #[test]
    fn create_item_roundtrip() {
        let msg = Message::CreateItem {
            id: 42,
            item: WireItem {
                key: 7,
                table: "replay".into(),
                priority: 1.5,
                chunk_keys: vec![1, 2, 3],
                offset: 1,
                length: 9,
                times_sampled: 0,
                columns: None,
            },
            timeout_ms: 500,
        };
        match roundtrip(&msg) {
            Message::CreateItem { id, item, timeout_ms } => {
                assert_eq!(id, 42);
                assert_eq!(item.table, "replay");
                assert_eq!(item.chunk_keys, vec![1, 2, 3]);
                assert_eq!(timeout_ms, 500);
            }
            other => panic!("wrong message {other:?}"),
        }
    }

    #[test]
    fn sample_flow_roundtrip() {
        let req = Message::SampleRequest {
            id: 1,
            table: "t".into(),
            num_samples: 8,
            timeout_ms: 100,
        };
        assert!(matches!(
            roundtrip(&req),
            Message::SampleRequest { num_samples: 8, .. }
        ));

        let resp = Message::SampleData {
            id: 1,
            infos: vec![WireSampleInfo {
                item: WireItem {
                    key: 7,
                    table: "t".into(),
                    priority: 0.5,
                    chunk_keys: vec![11],
                    offset: 0,
                    length: 2,
                    times_sampled: 3,
                    columns: None,
                },
                probability: 0.25,
                table_size: 100,
            }],
            chunks: vec![mk_chunk(11)],
        };
        match roundtrip(&resp) {
            Message::SampleData { infos, chunks, .. } => {
                assert_eq!(infos[0].probability, 0.25);
                assert_eq!(infos[0].table_size, 100);
                assert_eq!(chunks[0].key, 11);
            }
            other => panic!("wrong message {other:?}"),
        }
    }

    #[test]
    fn mutate_reset_info_ack_err_roundtrip() {
        let m = Message::MutatePriorities {
            id: 5,
            table: "t".into(),
            updates: vec![(1, 0.5), (2, 9.0)],
            deletes: vec![3],
        };
        assert!(
            matches!(roundtrip(&m), Message::MutatePriorities { updates, deletes, .. }
                if updates == vec![(1, 0.5), (2, 9.0)] && deletes == vec![3])
        );
        assert!(matches!(
            roundtrip(&Message::Reset { id: 1, table: "q".into() }),
            Message::Reset { .. }
        ));
        assert!(matches!(
            roundtrip(&Message::InfoRequest { id: 9 }),
            Message::InfoRequest { id: 9 }
        ));
        assert!(matches!(
            roundtrip(&Message::Checkpoint { id: 2 }),
            Message::Checkpoint { id: 2 }
        ));
        assert!(matches!(
            roundtrip(&Message::Ack { id: 3, detail: "ok".into() }),
            Message::Ack { id: 3, .. }
        ));
        match roundtrip(&Message::Err {
            id: 4,
            code: code::TIMEOUT,
            message: "slow".into(),
        }) {
            Message::Err { code: c, message, .. } => {
                assert_eq!(c, code::TIMEOUT);
                assert!(error_from_code(c, message).is_timeout());
            }
            other => panic!("wrong message {other:?}"),
        }
    }

    #[test]
    fn info_roundtrip() {
        let msg = Message::Info {
            id: 1,
            tables: vec![(
                "t".into(),
                TableInfo {
                    size: 5,
                    max_size: 10,
                    inserts: 100,
                    samples: 200,
                    rate_limited_inserts: 3,
                    rate_limited_samples: 4,
                    diff: -2.5,
                    total_weight: 12.25,
                },
            )],
        };
        match roundtrip(&msg) {
            Message::Info { tables, .. } => {
                assert_eq!(tables[0].0, "t");
                assert_eq!(tables[0].1.samples, 200);
                assert_eq!(tables[0].1.diff, -2.5);
                assert_eq!(tables[0].1.total_weight, 12.25);
            }
            other => panic!("wrong message {other:?}"),
        }
    }

    #[test]
    fn ping_pong_roundtrip() {
        assert!(matches!(
            roundtrip(&Message::Ping { id: 6, nonce: 0xdead_beef }),
            Message::Ping { id: 6, nonce: 0xdead_beef }
        ));
        assert!(matches!(
            roundtrip(&Message::Pong { id: 6, nonce: 0xdead_beef }),
            Message::Pong { id: 6, nonce: 0xdead_beef }
        ));
    }

    #[test]
    fn admin_reconfig_roundtrip() {
        // All fields present.
        let full = Message::AdminReconfig {
            id: 11,
            table: "t".into(),
            max_size: Some(4096),
            min_diff: Some(-8.0),
            max_diff: Some(8.0),
            checkpoint_interval_ms: Some(30_000),
            slow_request_micros: Some(250_000),
            trace_sample_per_mille: Some(10),
        };
        match roundtrip(&full) {
            Message::AdminReconfig {
                id,
                table,
                max_size,
                min_diff,
                max_diff,
                checkpoint_interval_ms,
                slow_request_micros,
                trace_sample_per_mille,
            } => {
                assert_eq!(id, 11);
                assert_eq!(table, "t");
                assert_eq!(max_size, Some(4096));
                assert_eq!(min_diff, Some(-8.0));
                assert_eq!(max_diff, Some(8.0));
                assert_eq!(checkpoint_interval_ms, Some(30_000));
                assert_eq!(slow_request_micros, Some(250_000));
                assert_eq!(trace_sample_per_mille, Some(10));
            }
            other => panic!("wrong message {other:?}"),
        }
        // Sparse: only one knob set, the rest None.
        let sparse = Message::AdminReconfig {
            id: 12,
            table: "t".into(),
            max_size: Some(10),
            min_diff: None,
            max_diff: None,
            checkpoint_interval_ms: None,
            slow_request_micros: None,
            trace_sample_per_mille: None,
        };
        assert!(matches!(
            roundtrip(&sparse),
            Message::AdminReconfig {
                max_size: Some(10),
                min_diff: None,
                max_diff: None,
                checkpoint_interval_ms: None,
                slow_request_micros: None,
                trace_sample_per_mille: None,
                ..
            }
        ));
    }

    #[test]
    fn watch_frames_roundtrip() {
        assert!(matches!(
            roundtrip(&Message::WatchRequest { id: 5, table: "w".into() }),
            Message::WatchRequest { id: 5, table } if table == "w"
        ));
        assert!(matches!(
            roundtrip(&Message::WatchCancel { id: 5 }),
            Message::WatchCancel { id: 5 }
        ));
        let upd = Message::WatchUpdate {
            id: 5,
            table: "w".into(),
            info: TableInfo {
                size: 3,
                max_size: 10,
                inserts: 7,
                samples: 2,
                rate_limited_inserts: 0,
                rate_limited_samples: 1,
                diff: 1.5,
                total_weight: 3.0,
            },
        };
        match roundtrip(&upd) {
            Message::WatchUpdate { id, table, info } => {
                assert_eq!(id, 5);
                assert_eq!(table, "w");
                assert_eq!(info.size, 3);
                assert_eq!(info.inserts, 7);
                assert_eq!(info.diff, 1.5);
                assert_eq!(info.total_weight, 3.0);
            }
            other => panic!("wrong message {other:?}"),
        }
    }

    #[test]
    fn bad_option_flag_rejected() {
        // AdminReconfig body with a corrupt presence flag (2) must error.
        let mut body = Vec::new();
        put_u64(&mut body, 1).unwrap();
        put_string(&mut body, "t").unwrap();
        put_u8(&mut body, 2).unwrap();
        assert!(Message::decode_body(TAG_ADMIN_RECONFIG, &body).is_err());
    }

    #[test]
    fn encode_frame_matches_write_frame_bytes() {
        for msg in [
            Message::InfoRequest { id: 7 },
            Message::Ack { id: 1, detail: "ok".into() },
            Message::InsertChunks { chunks: vec![mk_chunk(3)] },
            Message::PriorityUpdateBatch {
                id: 2,
                ops: vec![PriorityUpdateOp {
                    table: "t".into(),
                    updates: vec![(1, 2.0)],
                    deletes: vec![],
                }],
                trace: None,
            },
        ] {
            let mut streamed = Vec::new();
            msg.write_frame(&mut streamed).unwrap();
            assert_eq!(msg.encode_frame().unwrap(), streamed);
        }
    }

    #[test]
    fn unknown_tag_rejected() {
        assert!(Message::decode_body(200, &[]).is_err());
    }

    #[test]
    fn oversized_frame_rejected() {
        let mut buf = Vec::new();
        put_u32(&mut buf, u32::MAX).unwrap();
        put_u8(&mut buf, TAG_ACK).unwrap();
        assert!(Message::read_frame(&mut std::io::Cursor::new(buf)).is_err());
    }

    #[test]
    fn truncated_frame_rejected_at_every_cut() {
        // A valid frame cut short at any byte boundary must produce a clean
        // error (Io for missing bytes, Decode for malformed bodies) — never
        // a panic or a bogus message.
        let msg = Message::SampleData {
            id: 3,
            infos: vec![WireSampleInfo {
                item: WireItem {
                    key: 1,
                    table: "t".into(),
                    priority: 1.0,
                    chunk_keys: vec![11],
                    offset: 0,
                    length: 2,
                    times_sampled: 0,
                    columns: None,
                },
                probability: 0.5,
                table_size: 4,
            }],
            chunks: vec![mk_chunk(11)],
        };
        let mut full = Vec::new();
        msg.write_frame(&mut full).unwrap();
        for cut in 0..full.len() {
            let mut cursor = std::io::Cursor::new(&full[..cut]);
            assert!(
                Message::read_frame(&mut cursor).is_err(),
                "truncation at {cut}/{} was accepted",
                full.len()
            );
        }
        // And the intact frame still decodes.
        assert!(Message::read_frame(&mut std::io::Cursor::new(full)).is_ok());
    }

    #[test]
    fn truncated_body_with_valid_header_rejected() {
        // Header says the body is longer than what follows.
        let (tag, body) = Message::InfoRequest { id: 1 }.encode_body().unwrap();
        let mut buf = Vec::new();
        put_u32(&mut buf, body.len() as u32 + 64).unwrap();
        put_u8(&mut buf, tag).unwrap();
        buf.extend_from_slice(&body);
        assert!(Message::read_frame(&mut std::io::Cursor::new(buf)).is_err());
    }

    #[test]
    fn frame_length_limit_is_exact() {
        // MAX_FRAME_LEN itself is accepted by the length check (the read
        // then fails on missing bytes); one past it is rejected outright.
        let mut over = Vec::new();
        put_u32(&mut over, (MAX_FRAME_LEN + 1) as u32).unwrap();
        put_u8(&mut over, TAG_ACK).unwrap();
        let err = Message::read_frame(&mut std::io::Cursor::new(over)).unwrap_err();
        assert!(matches!(err, Error::Decode(_)), "{err}");
    }

    #[test]
    fn table_config_codec_roundtrip() {
        let cfg = TableConfig::prioritized_replay("per", 1000, 0.6, 4.0, 100, 40.0)
            .unwrap()
            .with_shards(6);
        let mut buf = Vec::new();
        encode_table_config(&mut buf, &cfg).unwrap();
        let back = decode_table_config(&mut std::io::Cursor::new(buf)).unwrap();
        assert_eq!(back.name, "per");
        assert_eq!(back.sampler, SelectorConfig::Prioritized { exponent: 0.6 });
        assert_eq!(back.max_size, 1000);
        assert_eq!(back.rate_limiter, cfg.rate_limiter);
        assert_eq!(back.num_shards, 6);
    }

    #[test]
    fn wire_roundtrip_property() {
        crate::util::proptest::forall("wire item roundtrip", |rng| {
            // Half the cases carry a trajectory column list (v2 layout).
            let columns = if rng.gen_range(2) == 0 {
                None
            } else {
                Some(Arc::new(
                    (0..rng.gen_range(4) + 1)
                        .map(|c| TrajectoryColumn {
                            name: format!("col_{c}"),
                            squeeze: rng.gen_range(2) == 0,
                            slices: (0..rng.gen_range(5) + 1)
                                .map(|_| ChunkSlice {
                                    chunk_key: rng.next_u64(),
                                    offset: rng.gen_range(100) as usize,
                                    length: rng.gen_range(100) as usize + 1,
                                })
                                .collect(),
                        })
                        .collect(),
                ))
            };
            let item = WireItem {
                key: rng.next_u64(),
                table: format!("table_{}", rng.gen_range(100)),
                priority: rng.gen_f64() * 100.0,
                chunk_keys: (0..rng.gen_range(10)).map(|_| rng.next_u64()).collect(),
                offset: rng.gen_range(1000),
                length: rng.gen_range(1000) + 1,
                times_sampled: rng.gen_range(100) as u32,
                columns,
            };
            let mut buf = Vec::new();
            put_wire_item_v2(&mut buf, &item).unwrap();
            let back = get_wire_item_v2(&mut std::io::Cursor::new(buf)).unwrap();
            if back == item {
                Ok(())
            } else {
                Err(format!("{back:?} != {item:?}"))
            }
        });
    }

    fn trajectory_item() -> WireItem {
        WireItem {
            key: 7,
            table: "traj".into(),
            priority: 2.0,
            chunk_keys: vec![11, 12],
            offset: 0,
            length: 3,
            times_sampled: 0,
            columns: Some(Arc::new(vec![
                TrajectoryColumn {
                    name: "obs".into(),
                    squeeze: false,
                    slices: vec![
                        ChunkSlice { chunk_key: 11, offset: 0, length: 2 },
                        ChunkSlice { chunk_key: 12, offset: 1, length: 1 },
                    ],
                },
                TrajectoryColumn {
                    name: "last".into(),
                    squeeze: true,
                    slices: vec![ChunkSlice { chunk_key: 12, offset: 0, length: 1 }],
                },
            ])),
        }
    }

    #[test]
    fn trajectory_create_item_uses_v2_frame_and_roundtrips() {
        let msg = Message::CreateItem {
            id: 3,
            item: trajectory_item(),
            timeout_ms: 250,
        };
        let (tag, _) = msg.encode_body().unwrap();
        assert_eq!(tag, TAG_CREATE_ITEM_V2);
        match roundtrip(&msg) {
            Message::CreateItem { item, timeout_ms, .. } => {
                assert_eq!(item, trajectory_item());
                assert_eq!(timeout_ms, 250);
            }
            other => panic!("wrong message {other:?}"),
        }
        // A flat item still encodes as the v1 frame — byte layout unchanged.
        let flat = Message::CreateItem {
            id: 3,
            item: WireItem { columns: None, ..trajectory_item() },
            timeout_ms: 250,
        };
        let (tag, _) = flat.encode_body().unwrap();
        assert_eq!(tag, TAG_CREATE_ITEM);
    }

    #[test]
    fn trajectory_sample_data_uses_v2_frame_and_roundtrips() {
        let msg = Message::SampleData {
            id: 9,
            infos: vec![
                WireSampleInfo {
                    item: trajectory_item(),
                    probability: 0.5,
                    table_size: 3,
                },
                // Mixed batch: a flat item rides the v2 frame unchanged.
                WireSampleInfo {
                    item: WireItem { columns: None, ..trajectory_item() },
                    probability: 0.25,
                    table_size: 3,
                },
            ],
            chunks: vec![mk_chunk(11)],
        };
        let (tag, _) = msg.encode_body().unwrap();
        assert_eq!(tag, TAG_SAMPLE_DATA_V2);
        match roundtrip(&msg) {
            Message::SampleData { infos, chunks, .. } => {
                assert_eq!(infos[0].item, trajectory_item());
                assert!(infos[1].item.columns.is_none());
                assert_eq!(chunks[0].key, 11);
            }
            other => panic!("wrong message {other:?}"),
        }
    }

    #[test]
    fn v1_frame_rejects_trajectory_items() {
        let mut buf = Vec::new();
        assert!(put_wire_item(&mut buf, &trajectory_item()).is_err());
    }

    fn flat_item(key: u64) -> WireItem {
        WireItem {
            key,
            table: "t".into(),
            priority: 1.0,
            chunk_keys: vec![11],
            offset: 0,
            length: 2,
            times_sampled: 0,
            columns: None,
        }
    }

    #[test]
    fn create_item_batch_roundtrip() {
        // Mixed batch: flat and trajectory items ride the same frame.
        let msg = Message::CreateItemBatch {
            id: 21,
            items: vec![flat_item(1), trajectory_item(), flat_item(3)],
            timeout_ms: 750,
            trace: None,
        };
        match roundtrip(&msg) {
            Message::CreateItemBatch {
                id,
                items,
                timeout_ms,
                trace,
            } => {
                assert_eq!(id, 21);
                assert_eq!(items.len(), 3);
                assert_eq!(items[0], flat_item(1));
                assert_eq!(items[1], trajectory_item());
                assert_eq!(timeout_ms, 750);
                assert_eq!(trace, None);
            }
            other => panic!("wrong message {other:?}"),
        }
    }

    #[test]
    fn priority_update_batch_roundtrip() {
        let msg = Message::PriorityUpdateBatch {
            id: 8,
            ops: vec![
                PriorityUpdateOp {
                    table: "a".into(),
                    updates: vec![(1, 0.5), (2, 2.0)],
                    deletes: vec![9],
                },
                PriorityUpdateOp {
                    table: "b".into(),
                    updates: vec![],
                    deletes: vec![],
                },
            ],
            trace: None,
        };
        match roundtrip(&msg) {
            Message::PriorityUpdateBatch { id, ops, .. } => {
                assert_eq!(id, 8);
                assert_eq!(ops.len(), 2);
                assert_eq!(ops[0].updates, vec![(1, 0.5), (2, 2.0)]);
                assert_eq!(ops[0].deletes, vec![9]);
                assert_eq!(ops[1].table, "b");
            }
            other => panic!("wrong message {other:?}"),
        }
    }

    #[test]
    fn batch_reply_roundtrip() {
        let msg = Message::BatchReply {
            id: 4,
            results: vec![
                BatchResult::Ok { detail: "inserted".into() },
                BatchResult::Err {
                    code: code::NOT_FOUND,
                    message: "table missing".into(),
                },
            ],
            trace: None,
        };
        match roundtrip(&msg) {
            Message::BatchReply { id, results, .. } => {
                assert_eq!(id, 4);
                assert_eq!(results[0].clone().into_result().unwrap(), "inserted");
                let err = results[1].clone().into_result().unwrap_err();
                assert!(matches!(err, Error::TableNotFound(_)), "{err}");
            }
            other => panic!("wrong message {other:?}"),
        }
    }

    #[test]
    fn v3_envelope_rejects_wrong_version_and_magic() {
        let (tag, body) = Message::PriorityUpdateBatch {
            id: 1,
            ops: vec![],
            trace: None,
        }
        .encode_body()
        .unwrap();
        assert_eq!(&body[..2], &ENVELOPE_MAGIC);
        assert_eq!(body[2], WIRE_VERSION);
        // A future version must fail with an explicit version message, not
        // a field-level decode error.
        let mut future = body.clone();
        future[2] = WIRE_VERSION + 1;
        let err = Message::decode_body(tag, &future).unwrap_err();
        assert!(err.to_string().contains("unsupported wire version"), "{err}");
        // Corrupt magic and reserved flags are rejected too.
        let mut bad_magic = body.clone();
        bad_magic[0] = b'X';
        assert!(Message::decode_body(tag, &bad_magic).is_err());
        let mut bad_flags = body;
        bad_flags[3] = 0x80;
        assert!(Message::decode_body(tag, &bad_flags).is_err());
    }

    #[test]
    fn v3_truncated_frame_rejected_at_every_cut() {
        // The existing every-cut property extended to v3 envelopes: a
        // batch frame cut anywhere (inside the envelope, an item, or the
        // trailing timeout) errors cleanly.
        let msg = Message::CreateItemBatch {
            id: 2,
            items: vec![flat_item(1), trajectory_item()],
            timeout_ms: 100,
            trace: Some(TraceContext {
                trace_id: 0xAAAA_BBBB,
                span_id: 0xCCCC_DDDD,
                sampled: true,
            }),
        };
        let mut full = Vec::new();
        msg.write_frame(&mut full).unwrap();
        for cut in 0..full.len() {
            let mut cursor = std::io::Cursor::new(&full[..cut]);
            assert!(
                Message::read_frame(&mut cursor).is_err(),
                "truncation at {cut}/{} was accepted",
                full.len()
            );
        }
        assert!(Message::read_frame(&mut std::io::Cursor::new(full)).is_ok());
    }

    #[test]
    fn v3_decode_caps_reject_corrupt_counts() {
        // A corrupt op count past the decode cap errors without allocating.
        let mut body = Vec::new();
        put_envelope(&mut body, None).unwrap();
        put_u64(&mut body, 1).unwrap();
        put_u32(&mut body, (1 << 20) + 1).unwrap();
        assert!(Message::decode_body(TAG_PRIORITY_UPDATE_BATCH, &body).is_err());
        let mut items = Vec::new();
        put_envelope(&mut items, None).unwrap();
        put_u64(&mut items, 1).unwrap();
        put_u32(&mut items, (1 << 20) + 1).unwrap();
        assert!(Message::decode_body(TAG_CREATE_ITEM_BATCH, &items).is_err());
        let mut results = Vec::new();
        put_envelope(&mut results, None).unwrap();
        put_u64(&mut results, 1).unwrap();
        put_u32(&mut results, (1 << 20) + 1).unwrap();
        assert!(Message::decode_body(TAG_BATCH_REPLY, &results).is_err());
    }

    #[test]
    fn trace_context_rides_the_envelope_flag_bit() {
        let ctx = TraceContext {
            trace_id: 0x0123_4567_89AB_CDEF,
            span_id: 0xFEDC_BA98_7654_3210,
            sampled: true,
        };
        for msg in [
            Message::CreateItemBatch {
                id: 1,
                items: vec![flat_item(1)],
                timeout_ms: 50,
                trace: Some(ctx),
            },
            Message::PriorityUpdateBatch {
                id: 2,
                ops: vec![],
                trace: Some(ctx),
            },
            Message::BatchReply {
                id: 3,
                results: vec![BatchResult::Ok { detail: "ok".into() }],
                trace: Some(ctx),
            },
        ] {
            let (_, body) = msg.encode_body().unwrap();
            assert_eq!(body[3], FLAG_TRACE, "flag bit set when trace present");
            let decoded = roundtrip(&msg);
            let got = match decoded {
                Message::CreateItemBatch { trace, .. }
                | Message::PriorityUpdateBatch { trace, .. }
                | Message::BatchReply { trace, .. } => trace,
                other => panic!("wrong message {other:?}"),
            };
            assert_eq!(got, Some(ctx));
        }
        // sampled=false round-trips too.
        let unsampled = Message::BatchReply {
            id: 4,
            results: vec![],
            trace: Some(TraceContext { sampled: false, ..ctx }),
        };
        assert!(matches!(
            roundtrip(&unsampled),
            Message::BatchReply { trace: Some(TraceContext { sampled: false, .. }), .. }
        ));
    }

    #[test]
    fn untraced_batch_frames_are_byte_identical_to_pre_trace_v3() {
        // trace=None keeps the flags byte 0 and adds no bytes: the frame an
        // untagged/pre-tracing peer sees is exactly the old layout —
        // envelope, id, count, payload — with nothing in between.
        let msg = Message::CreateItemBatch {
            id: 0x1122_3344_5566_7788,
            items: vec![],
            timeout_ms: 9,
            trace: None,
        };
        let (_, body) = msg.encode_body().unwrap();
        let mut expected = Vec::new();
        expected.extend_from_slice(&ENVELOPE_MAGIC);
        put_u8(&mut expected, WIRE_VERSION).unwrap();
        put_u8(&mut expected, 0).unwrap();
        put_u64(&mut expected, 0x1122_3344_5566_7788).unwrap();
        put_u32(&mut expected, 0).unwrap();
        put_u64(&mut expected, 9).unwrap();
        assert_eq!(body, expected);
    }

    #[test]
    fn corrupt_trace_extension_rejected() {
        // Truncated trace payload after the flag bit.
        let mut body = Vec::new();
        body.extend_from_slice(&ENVELOPE_MAGIC);
        put_u8(&mut body, WIRE_VERSION).unwrap();
        put_u8(&mut body, FLAG_TRACE).unwrap();
        put_u64(&mut body, 1).unwrap(); // trace_id only, then EOF
        assert!(Message::decode_body(TAG_BATCH_REPLY, &body).is_err());
        // Bad sampled byte (2) is rejected, not coerced.
        let mut bad = Vec::new();
        bad.extend_from_slice(&ENVELOPE_MAGIC);
        put_u8(&mut bad, WIRE_VERSION).unwrap();
        put_u8(&mut bad, FLAG_TRACE).unwrap();
        put_u64(&mut bad, 1).unwrap();
        put_u64(&mut bad, 2).unwrap();
        put_u8(&mut bad, 2).unwrap();
        put_u64(&mut bad, 3).unwrap(); // id
        put_u32(&mut bad, 0).unwrap(); // count
        let err = Message::decode_body(TAG_BATCH_REPLY, &bad).unwrap_err();
        assert!(err.to_string().contains("bad trace sampled flag"), "{err}");
    }

    /// A reader that yields its script one slice at a time, interleaving
    /// `WouldBlock` between slices — the shape of a nonblocking socket.
    struct Trickle {
        data: Vec<u8>,
        pos: usize,
        step: usize,
        blocked: bool,
    }

    impl std::io::Read for Trickle {
        fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
            if !self.blocked {
                self.blocked = true;
                return Err(std::io::Error::new(std::io::ErrorKind::WouldBlock, "nb"));
            }
            self.blocked = false;
            if self.pos >= self.data.len() {
                return Ok(0);
            }
            let n = self.step.min(buf.len()).min(self.data.len() - self.pos);
            buf[..n].copy_from_slice(&self.data[self.pos..self.pos + n]);
            self.pos += n;
            Ok(n)
        }
    }

    #[test]
    fn frame_decoder_resumes_across_would_block_at_every_byte_granularity() {
        // Three pipelined frames delivered 1..=7 bytes at a time with a
        // WouldBlock before every read: the decoder must suspend and
        // resume mid-header and mid-body without losing or reordering
        // frames.
        let msgs = vec![
            Message::InfoRequest { id: 1 },
            Message::InsertChunks { chunks: vec![mk_chunk(4)] },
            Message::Ack { id: 2, detail: "done".into() },
        ];
        let mut wire = Vec::new();
        for m in &msgs {
            m.write_frame(&mut wire).unwrap();
        }
        for step in 1..=7usize {
            let mut r = Trickle {
                data: wire.clone(),
                pos: 0,
                step,
                blocked: false,
            };
            let mut dec = FrameDecoder::new();
            let mut got = Vec::new();
            loop {
                match dec.read_from(&mut r) {
                    Ok(Some(m)) => got.push(m),
                    Ok(None) => continue, // would-block: re-drive
                    Err(Error::Io(e)) => {
                        assert_eq!(e.kind(), std::io::ErrorKind::UnexpectedEof);
                        break;
                    }
                    Err(e) => panic!("step {step}: {e}"),
                }
            }
            assert_eq!(got.len(), 3, "step {step}");
            assert!(matches!(got[0], Message::InfoRequest { id: 1 }));
            assert!(matches!(&got[1], Message::InsertChunks { chunks } if chunks[0].key == 4));
            assert!(matches!(&got[2], Message::Ack { id: 2, .. }));
            assert!(!dec.mid_frame(), "step {step}: no stranded bytes");
        }
    }

    #[test]
    fn frame_decoder_eof_mid_frame_is_unexpected_eof() {
        let mut wire = Vec::new();
        Message::Ack { id: 9, detail: "x".into() }
            .write_frame(&mut wire)
            .unwrap();
        wire.truncate(wire.len() - 1);
        let mut dec = FrameDecoder::new();
        let mut cursor = std::io::Cursor::new(wire);
        match dec.read_from(&mut cursor) {
            Err(Error::Io(e)) => assert_eq!(e.kind(), std::io::ErrorKind::UnexpectedEof),
            other => panic!("expected eof error, got {other:?}"),
        }
        assert!(dec.mid_frame());
    }

    #[test]
    fn frame_decoder_rejects_oversized_length_without_reading_body() {
        let mut buf = Vec::new();
        put_u32(&mut buf, (MAX_FRAME_LEN + 1) as u32).unwrap();
        put_u8(&mut buf, TAG_ACK).unwrap();
        let mut dec = FrameDecoder::new();
        let mut cursor = std::io::Cursor::new(buf);
        assert!(matches!(dec.read_from(&mut cursor), Err(Error::Decode(_))));
    }

    #[test]
    fn frame_decoder_drains_buffered_frames_before_reading() {
        // Both frames arrive in one read; the second must come out of the
        // stash without touching the reader again.
        let mut wire = Vec::new();
        Message::InfoRequest { id: 1 }.write_frame(&mut wire).unwrap();
        Message::InfoRequest { id: 2 }.write_frame(&mut wire).unwrap();
        let mut dec = FrameDecoder::new();
        let mut cursor = std::io::Cursor::new(wire);
        assert!(matches!(
            dec.read_from(&mut cursor).unwrap(),
            Some(Message::InfoRequest { id: 1 })
        ));
        let mut dead = std::io::empty();
        assert!(matches!(
            dec.read_from(&mut dead).unwrap(),
            Some(Message::InfoRequest { id: 2 })
        ));
    }
}
