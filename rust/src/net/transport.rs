//! Pluggable transport (DESIGN.md §2, §11): framed [`Message`] streams
//! between clients and servers, with three interchangeable backends.
//!
//! - **`tcp://host:port`** (bare `host:port` also accepted) — the original
//!   path: length-prefixed frames over a `TcpStream`, `Message`s encoded and
//!   decoded at each end.
//! - **`reverb+unix:///path`** — the same frame codec over a Unix domain
//!   socket: loopback traffic without the TCP/IP stack (ROADMAP transport
//!   backends item).
//! - **`reverb://in-proc/<name>`** — a zero-copy in-process path: whole
//!   [`Message`] values move through channels (requests bounded for
//!   backpressure, replies unbounded for deadlock freedom — see
//!   [`CHANNEL_DEPTH`]), so chunk payloads (`Arc<Chunk>`) are *shared*,
//!   never serialized, copied, or pushed through a syscall. This is the
//!   default data plane for same-process actor/learner harnesses
//!   (`coordinator`), where the paper notes the throughput ceiling should
//!   live in the tables, not the transport.
//!
//! All backends carry the identical protocol and error mapping: a closed
//! peer surfaces as [`Error::Io`], exactly like a TCP hang-up, so every
//! layer above (`Server`, `Client`, `Writer`, `Sampler`) is
//! transport-oblivious. The conformance suite in
//! `rust/tests/transport_conformance.rs` runs every black-box scenario
//! against all backends.
//!
//! # Readiness API (the event-driven service core, DESIGN.md §11)
//!
//! Every stream also exposes a non-blocking face: [`MsgStream::set_nonblocking`],
//! [`MsgStream::try_recv`] (resumable frame decode via
//! [`crate::net::wire::FrameDecoder`] — a partial frame survives a
//! `WouldBlock` and resumes on the next readiness event),
//! [`MsgStream::try_flush`] (partial-write resumption over the vectored
//! write queue), and [`MsgStream::poll_source`] — fd-backed streams hand
//! their descriptor to the server's poller
//! ([`crate::net::poller::Poller`]); channel-backed streams report
//! readiness by occupancy and push wakeups through
//! [`MsgStream::set_ready_waker`] instead. The blocking `recv`/`flush`
//! methods are implemented *on top of* the same decoder and write queue,
//! so the blocking client API routes over the identical nonblocking
//! machinery.

use crate::error::{Error, Result};
use crate::net::wire::{FrameDecoder, Message};
use std::collections::HashMap;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, sync_channel, Receiver, Sender, SyncSender, TryRecvError};
use std::sync::{Arc, Mutex, OnceLock};

/// URL prefix of the in-process backend.
pub const IN_PROC_SCHEME: &str = "reverb://in-proc/";

/// URL prefix of the Unix-domain-socket backend (`reverb+unix:///path`).
pub const UNIX_SCHEME: &str = "reverb+unix://";

/// Request-direction (client→server) messages buffered on an in-process
/// connection. Bounded so requests see the same backpressure a full TCP
/// socket buffer would. The reply direction is deliberately *unbounded*:
/// a server that can never block on replies always drains requests, which
/// rules out the request-full/reply-full deadlock for arbitrarily large
/// client pipelining windows; reply memory stays bounded by the client's
/// outstanding-request window for any client that reads its replies.
const CHANNEL_DEPTH: usize = 256;

/// Pending, not-yet-accepted connections per in-process listener.
const ACCEPT_BACKLOG: usize = 64;

/// Where a stream's readiness signal comes from (see module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PollSource {
    /// Poll this file descriptor (TCP / Unix sockets).
    Fd(i32),
    /// Channel-backed: readiness is channel occupancy, delivered through
    /// [`MsgStream::set_ready_waker`]; there is nothing to poll.
    Channel,
}

/// A bidirectional, framed [`Message`] stream. `send` may buffer until
/// `flush`; `recv` blocks for the next message. A closed peer yields
/// [`Error::Io`] from `recv`/`send`, mirroring TCP semantics.
///
/// The `try_*` half is the readiness face used by the event-driven server
/// core; the blocking half is implemented over the same buffers, so both
/// service models and the client share one code path per backend.
pub trait MsgStream: Send {
    fn send(&mut self, msg: Message) -> Result<()>;
    fn flush(&mut self) -> Result<()>;
    fn recv(&mut self) -> Result<Message>;
    /// Backend name for diagnostics ("tcp" / "unix" / "in-proc").
    fn transport(&self) -> &'static str;

    // ---- readiness API (event-driven core) ----

    /// Switch the underlying socket into (or out of) non-blocking mode.
    /// Channel-backed streams are readiness-native; for them this is a
    /// no-op.
    fn set_nonblocking(&mut self, nonblocking: bool) -> Result<()>;

    /// Registration token for the server's poller.
    fn poll_source(&self) -> PollSource;

    /// Non-blocking receive: `Ok(Some)` = one frame, `Ok(None)` = would
    /// block (no complete frame available right now; a partial frame stays
    /// buffered and resumes later), `Err` = peer closed / protocol error.
    fn try_recv(&mut self) -> Result<Option<Message>>;

    /// Non-blocking flush of queued outbound frames: `Ok(true)` = fully
    /// flushed, `Ok(false)` = the peer's buffer filled mid-queue (re-arm
    /// for writability and resume later).
    fn try_flush(&mut self) -> Result<bool>;

    /// Channel-backed streams invoke `waker` whenever a message becomes
    /// available (and immediately if one already is). Fd-backed streams
    /// ignore this — their readiness comes from the poller.
    fn set_ready_waker(&mut self, _waker: Arc<dyn Fn() + Send + Sync>) {}
}

/// Server side of a transport: blocks for inbound connections.
pub trait TransportListener: Send {
    /// Next connection. `Ok(None)` means the listener was shut down.
    fn accept(&mut self) -> Result<Option<Box<dyn MsgStream>>>;
    /// The endpoint string clients dial to reach this listener.
    fn endpoint(&self) -> String;
}

/// Connect to an endpoint by URL. Dispatches on scheme:
/// `reverb://in-proc/<name>` (or `inproc://<name>`) to the channel backend,
/// `reverb+unix:///path` to a Unix domain socket,
/// `reverb+pool://a,b,...` to the replay-fabric facade
/// ([`crate::client::fabric`]), and `tcp://host:port` or bare `host:port`
/// to TCP.
pub fn dial(addr: &str) -> Result<Box<dyn MsgStream>> {
    if let Some(name) = addr.strip_prefix(IN_PROC_SCHEME) {
        return Ok(Box::new(dial_in_proc(name)?));
    }
    if let Some(name) = addr.strip_prefix("inproc://") {
        return Ok(Box::new(dial_in_proc(name)?));
    }
    if let Some(path) = addr.strip_prefix(UNIX_SCHEME) {
        #[cfg(unix)]
        {
            return Ok(Box::new(UnixMsgStream::connect_unix(path)?));
        }
        #[cfg(not(unix))]
        {
            let _ = path;
            return Err(Error::InvalidArgument(
                "unix-domain sockets are not supported on this platform".into(),
            ));
        }
    }
    if let Some(spec) = addr.strip_prefix(crate::client::fabric::POOL_SCHEME) {
        // Replay fabric (DESIGN.md §14): one facade stream over N servers.
        return crate::client::fabric::open_stream(spec);
    }
    let hostport = addr.strip_prefix("tcp://").unwrap_or(addr);
    Ok(Box::new(TcpMsgStream::connect(hostport)?))
}

// ---------------------------------------------------------------------
// Socket backends (TCP + Unix): one generic frame stream
// ---------------------------------------------------------------------

/// Auto-flush threshold for queued outbound frames: matches the old
/// `BufWriter` capacity so memory stays bounded under deep pipelining.
const SEND_QUEUE_FLUSH_BYTES: usize = 256 * 1024;

/// The socket operations a [`SocketMsgStream`] needs, shared by
/// `TcpStream` and `UnixStream` (both implement `Read`/`Write` for `&Self`,
/// which is what lets one object serve reads and vectored writes without
/// `try_clone`).
pub trait RawSock: Send {
    fn read_some(&self, buf: &mut [u8]) -> std::io::Result<usize>;
    fn write_vectored_some(&self, bufs: &[std::io::IoSlice<'_>]) -> std::io::Result<usize>;
    fn set_nb(&self, nonblocking: bool) -> std::io::Result<()>;
    fn raw_fd(&self) -> i32;
    fn label(&self) -> &'static str;
}

impl RawSock for TcpStream {
    fn read_some(&self, buf: &mut [u8]) -> std::io::Result<usize> {
        let mut s = self;
        std::io::Read::read(&mut s, buf)
    }
    fn write_vectored_some(&self, bufs: &[std::io::IoSlice<'_>]) -> std::io::Result<usize> {
        let mut s = self;
        std::io::Write::write_vectored(&mut s, bufs)
    }
    fn set_nb(&self, nonblocking: bool) -> std::io::Result<()> {
        self.set_nonblocking(nonblocking)
    }
    fn raw_fd(&self) -> i32 {
        #[cfg(unix)]
        {
            std::os::unix::io::AsRawFd::as_raw_fd(self)
        }
        #[cfg(not(unix))]
        {
            -1
        }
    }
    fn label(&self) -> &'static str {
        "tcp"
    }
}

#[cfg(unix)]
impl RawSock for std::os::unix::net::UnixStream {
    fn read_some(&self, buf: &mut [u8]) -> std::io::Result<usize> {
        let mut s = self;
        std::io::Read::read(&mut s, buf)
    }
    fn write_vectored_some(&self, bufs: &[std::io::IoSlice<'_>]) -> std::io::Result<usize> {
        let mut s = self;
        std::io::Write::write_vectored(&mut s, bufs)
    }
    fn set_nb(&self, nonblocking: bool) -> std::io::Result<()> {
        self.set_nonblocking(nonblocking)
    }
    fn raw_fd(&self) -> i32 {
        std::os::unix::io::AsRawFd::as_raw_fd(self)
    }
    fn label(&self) -> &'static str {
        "unix"
    }
}

/// Frame codec over one stream socket with a vectored write path:
/// `send` encodes each frame into its own buffer and queues it; `flush`
/// hands the whole queue to `write_vectored`, so a pipelined burst of
/// small frames (chunk streams + item creations, ack trains) is one
/// `writev` syscall instead of one `write` per frame — with no
/// intermediate copy into a staging buffer.
///
/// The read path is a [`FrameDecoder`], so the same object serves blocking
/// callers (`recv` loops until a frame completes) and the event core
/// (`try_recv` suspends at `WouldBlock` and resumes mid-frame).
pub struct SocketMsgStream<S: RawSock> {
    sock: S,
    decoder: FrameDecoder,
    /// Encoded frames awaiting the next flush.
    pending: std::collections::VecDeque<Vec<u8>>,
    /// Bytes of `pending[0]` already written by a previous partial flush.
    head: usize,
    pending_bytes: usize,
}

/// The TCP backend (kept under its historical name).
pub type TcpMsgStream = SocketMsgStream<TcpStream>;

/// The Unix-domain-socket backend.
#[cfg(unix)]
pub type UnixMsgStream = SocketMsgStream<std::os::unix::net::UnixStream>;

impl<S: RawSock> SocketMsgStream<S> {
    fn new(sock: S) -> Self {
        SocketMsgStream {
            sock,
            decoder: FrameDecoder::new(),
            pending: std::collections::VecDeque::new(),
            head: 0,
            pending_bytes: 0,
        }
    }

    /// Write queued frames with as few `writev` calls as the kernel
    /// allows, handling partial writes across frame boundaries. Returns
    /// `Ok(false)` when the socket reports `WouldBlock` mid-queue
    /// (non-blocking mode): the remainder stays queued for resumption.
    fn flush_pending(&mut self) -> Result<bool> {
        while !self.pending.is_empty() {
            let written = {
                let mut slices: Vec<std::io::IoSlice<'_>> =
                    Vec::with_capacity(self.pending.len());
                let mut iter = self.pending.iter();
                if let Some(first) = iter.next() {
                    slices.push(std::io::IoSlice::new(&first[self.head..]));
                }
                for buf in iter {
                    slices.push(std::io::IoSlice::new(buf));
                }
                match self.sock.write_vectored_some(&slices) {
                    Ok(0) => {
                        return Err(Error::Io(std::io::Error::new(
                            std::io::ErrorKind::WriteZero,
                            "peer stopped accepting frame bytes",
                        )))
                    }
                    Ok(n) => n,
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        return Ok(false);
                    }
                    Err(e) => return Err(e.into()),
                }
            };
            self.consume_pending(written);
        }
        self.head = 0;
        self.pending_bytes = 0;
        Ok(true)
    }

    /// Drop `n` written bytes off the front of the queue, keeping the
    /// auto-flush byte counter in sync even when a later `writev` in the
    /// same flush fails (the retry path must not see a stale count).
    fn consume_pending(&mut self, mut n: usize) {
        self.pending_bytes = self.pending_bytes.saturating_sub(n);
        while n > 0 {
            let first_remaining = self.pending[0].len() - self.head;
            if n >= first_remaining {
                n -= first_remaining;
                self.pending.pop_front();
                self.head = 0;
            } else {
                self.head += n;
                n = 0;
            }
        }
    }
}

impl SocketMsgStream<TcpStream> {
    pub fn connect(addr: &str) -> Result<TcpMsgStream> {
        Self::from_stream(TcpStream::connect(addr)?)
    }

    pub fn from_stream(stream: TcpStream) -> Result<TcpMsgStream> {
        stream.set_nodelay(true)?;
        Ok(Self::new(stream))
    }
}

#[cfg(unix)]
impl SocketMsgStream<std::os::unix::net::UnixStream> {
    pub fn connect_unix(path: &str) -> Result<UnixMsgStream> {
        Ok(Self::new(std::os::unix::net::UnixStream::connect(path)?))
    }

    pub fn from_unix_stream(stream: std::os::unix::net::UnixStream) -> Result<UnixMsgStream> {
        Ok(Self::new(stream))
    }
}

impl<S: RawSock> Drop for SocketMsgStream<S> {
    /// Best-effort flush of queued frames, restoring the flush-on-drop
    /// safety net the old `BufWriter` writer provided. (In non-blocking
    /// mode this is a single attempt — whatever the socket refuses is
    /// dropped with the connection, exactly like a TCP reset.)
    fn drop(&mut self) {
        let _ = self.flush_pending();
    }
}

impl<S: RawSock> MsgStream for SocketMsgStream<S> {
    fn send(&mut self, msg: Message) -> Result<()> {
        let frame = msg.encode_frame()?;
        self.pending_bytes += frame.len();
        self.pending.push_back(frame);
        if self.pending_bytes >= SEND_QUEUE_FLUSH_BYTES {
            // Blocking mode: drain fully (bounded memory). Non-blocking
            // mode: opportunistic single pass — the event core re-arms for
            // writability when the socket pushes back.
            self.flush_pending()?;
        }
        Ok(())
    }

    fn flush(&mut self) -> Result<()> {
        loop {
            if self.flush_pending()? {
                return Ok(());
            }
            // Only reachable on a non-blocking socket whose caller asked
            // for blocking semantics; yield briefly rather than spin.
            std::thread::sleep(std::time::Duration::from_micros(100));
        }
    }

    fn recv(&mut self) -> Result<Message> {
        loop {
            if let Some(msg) = self.decoder.read_from(&mut ReadAdapter(&self.sock))? {
                return Ok(msg);
            }
            // Only reachable on a non-blocking socket whose caller asked
            // for blocking semantics (the event core uses try_recv).
            std::thread::sleep(std::time::Duration::from_micros(100));
        }
    }

    fn transport(&self) -> &'static str {
        self.sock.label()
    }

    fn set_nonblocking(&mut self, nonblocking: bool) -> Result<()> {
        self.sock.set_nb(nonblocking)?;
        Ok(())
    }

    fn poll_source(&self) -> PollSource {
        PollSource::Fd(self.sock.raw_fd())
    }

    fn try_recv(&mut self) -> Result<Option<Message>> {
        self.decoder.read_from(&mut ReadAdapter(&self.sock))
    }

    fn try_flush(&mut self) -> Result<bool> {
        self.flush_pending()
    }
}

/// Adapts `&S` (shared-reference reads) to `std::io::Read` for the frame
/// decoder.
struct ReadAdapter<'a, S: RawSock>(&'a S);

impl<S: RawSock> std::io::Read for ReadAdapter<'_, S> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        self.0.read_some(buf)
    }
}

/// TCP listener half.
pub struct TcpTransportListener {
    listener: TcpListener,
    local: SocketAddr,
}

impl TcpTransportListener {
    pub fn bind(addr: &str) -> Result<TcpTransportListener> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        Ok(TcpTransportListener { listener, local })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.local
    }
}

impl TransportListener for TcpTransportListener {
    fn accept(&mut self) -> Result<Option<Box<dyn MsgStream>>> {
        let (stream, _peer) = self.listener.accept()?;
        Ok(Some(Box::new(TcpMsgStream::from_stream(stream)?)))
    }

    fn endpoint(&self) -> String {
        format!("tcp://{}", self.local)
    }
}

/// Unix-domain-socket listener half. Removes its socket file on drop.
#[cfg(unix)]
pub struct UnixTransportListener {
    listener: std::os::unix::net::UnixListener,
    path: std::path::PathBuf,
}

#[cfg(unix)]
impl UnixTransportListener {
    pub fn bind(path: impl Into<std::path::PathBuf>) -> Result<UnixTransportListener> {
        let path = path.into();
        let listener = std::os::unix::net::UnixListener::bind(&path)?;
        Ok(UnixTransportListener { listener, path })
    }

    pub fn path(&self) -> &std::path::Path {
        &self.path
    }
}

#[cfg(unix)]
impl Drop for UnixTransportListener {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.path);
    }
}

#[cfg(unix)]
impl TransportListener for UnixTransportListener {
    fn accept(&mut self) -> Result<Option<Box<dyn MsgStream>>> {
        let (stream, _peer) = self.listener.accept()?;
        Ok(Some(Box::new(UnixMsgStream::from_unix_stream(stream)?)))
    }

    fn endpoint(&self) -> String {
        format!("{UNIX_SCHEME}{}", self.path.display())
    }
}

// ---------------------------------------------------------------------
// In-process backend
// ---------------------------------------------------------------------

/// Sending half of an in-process direction: requests are bounded
/// (backpressure), replies unbounded (deadlock freedom) — see
/// [`CHANNEL_DEPTH`].
enum Tx {
    Bounded(SyncSender<Message>),
    Unbounded(Sender<Message>),
}

impl Tx {
    fn send(&self, msg: Message) -> std::result::Result<(), ()> {
        match self {
            Tx::Bounded(tx) => tx.send(msg).map_err(|_| ()),
            Tx::Unbounded(tx) => tx.send(msg).map_err(|_| ()),
        }
    }
}

/// A registered readiness callback for one in-process direction: the
/// sender fires it after every delivery, the receiver installs it.
#[derive(Default)]
struct WakerSlot(Mutex<Option<Arc<dyn Fn() + Send + Sync>>>);

impl WakerSlot {
    fn fire(&self) {
        let waker = self.0.lock().unwrap().clone();
        if let Some(w) = waker {
            w();
        }
    }
}

/// One direction-pair of channels. Chunk payloads inside the `Message` are
/// `Arc<Chunk>` handles, so moving a message through the channel shares
/// the payload instead of copying it.
///
/// Readiness: each direction tracks occupancy in an atomic; the sender
/// fires the receiver's waker after every delivery, which is how the
/// event-driven server learns a connection has input without any fd to
/// poll (`poll_source` = [`PollSource::Channel`]).
pub struct ChannelMsgStream {
    /// `None` once dropped: the sender is released *before* the peer's
    /// waker fires, so an event-driven peer that wakes on our departure
    /// observes the disconnect deterministically.
    tx: Option<Tx>,
    rx: Receiver<Message>,
    /// Messages sitting in `rx` (incremented by the peer's send).
    rx_count: Arc<AtomicUsize>,
    /// Messages sitting in the peer's receive queue.
    tx_count: Arc<AtomicUsize>,
    /// My readiness callback; the peer's send fires it.
    rx_waker: Arc<WakerSlot>,
    /// The peer's readiness callback; my send fires it.
    tx_waker: Arc<WakerSlot>,
}

impl Drop for ChannelMsgStream {
    /// Release the send half, then wake the peer: an event-driven server
    /// whose in-proc client vanished must get one last readiness signal so
    /// its `try_recv` observes the disconnect and the connection is torn
    /// down (transient RPC connections would otherwise accumulate).
    fn drop(&mut self) {
        self.tx = None;
        self.tx_waker.fire();
    }
}

fn peer_closed() -> Error {
    Error::Io(std::io::Error::new(
        std::io::ErrorKind::BrokenPipe,
        "in-proc peer closed",
    ))
}

impl MsgStream for ChannelMsgStream {
    fn send(&mut self, msg: Message) -> Result<()> {
        self.tx
            .as_ref()
            .ok_or_else(peer_closed)?
            .send(msg)
            .map_err(|()| peer_closed())?;
        self.tx_count.fetch_add(1, Ordering::SeqCst);
        self.tx_waker.fire();
        Ok(())
    }

    fn flush(&mut self) -> Result<()> {
        Ok(())
    }

    fn recv(&mut self) -> Result<Message> {
        let msg = self.rx.recv().map_err(|_| peer_closed())?;
        self.rx_count.fetch_sub(1, Ordering::SeqCst);
        Ok(msg)
    }

    fn transport(&self) -> &'static str {
        "in-proc"
    }

    fn set_nonblocking(&mut self, _nonblocking: bool) -> Result<()> {
        Ok(()) // channels are readiness-native
    }

    fn poll_source(&self) -> PollSource {
        PollSource::Channel
    }

    fn try_recv(&mut self) -> Result<Option<Message>> {
        match self.rx.try_recv() {
            Ok(msg) => {
                self.rx_count.fetch_sub(1, Ordering::SeqCst);
                Ok(Some(msg))
            }
            Err(TryRecvError::Empty) => Ok(None),
            Err(TryRecvError::Disconnected) => Err(peer_closed()),
        }
    }

    fn try_flush(&mut self) -> Result<bool> {
        Ok(true) // sends are delivered immediately (replies unbounded)
    }

    fn set_ready_waker(&mut self, waker: Arc<dyn Fn() + Send + Sync>) {
        *self.rx_waker.0.lock().unwrap() = Some(waker.clone());
        // Close the registration race: messages delivered before the waker
        // was installed must still produce a wakeup.
        if self.rx_count.load(Ordering::SeqCst) > 0 {
            waker();
        }
    }
}

/// Build a connected pair of in-process streams (client side, server
/// side). The client→server direction is bounded, server→client
/// unbounded — see [`CHANNEL_DEPTH`] for why.
pub fn channel_pair() -> (ChannelMsgStream, ChannelMsgStream) {
    let (tx_c2s, rx_c2s) = sync_channel(CHANNEL_DEPTH);
    let (tx_s2c, rx_s2c) = channel();
    let c2s_count = Arc::new(AtomicUsize::new(0));
    let s2c_count = Arc::new(AtomicUsize::new(0));
    let client_waker = Arc::new(WakerSlot::default());
    let server_waker = Arc::new(WakerSlot::default());
    (
        ChannelMsgStream {
            tx: Some(Tx::Bounded(tx_c2s)),
            rx: rx_s2c,
            rx_count: s2c_count.clone(),
            tx_count: c2s_count.clone(),
            rx_waker: client_waker.clone(),
            tx_waker: server_waker.clone(),
        },
        ChannelMsgStream {
            tx: Some(Tx::Unbounded(tx_s2c)),
            rx: rx_c2s,
            rx_count: c2s_count,
            tx_count: s2c_count,
            rx_waker: server_waker,
            tx_waker: client_waker,
        },
    )
}

/// A registered in-proc endpoint: the accept-queue sender plus a unique
/// token so a stale listener's `Drop` can never unbind a newer endpoint
/// that reused its name.
struct RegisteredEndpoint {
    token: u64,
    tx: SyncSender<ChannelMsgStream>,
}

/// Process-wide endpoint registry: in-proc listeners register here; `dial`
/// looks the name up and hands the listener the server half of a fresh
/// channel pair.
fn registry() -> &'static Mutex<HashMap<String, RegisteredEndpoint>> {
    static REGISTRY: OnceLock<Mutex<HashMap<String, RegisteredEndpoint>>> = OnceLock::new();
    REGISTRY.get_or_init(|| Mutex::new(HashMap::new()))
}

fn next_token() -> u64 {
    static NEXT: AtomicU64 = AtomicU64::new(0);
    NEXT.fetch_add(1, Ordering::Relaxed)
}

fn unique_name() -> String {
    format!("srv-{}-{}", std::process::id(), next_token())
}

fn dial_in_proc(name: &str) -> Result<ChannelMsgStream> {
    let refused = || {
        Error::Io(std::io::Error::new(
            std::io::ErrorKind::ConnectionRefused,
            format!("no in-proc server at {name:?}"),
        ))
    };
    let tx = registry()
        .lock()
        .unwrap()
        .get(name)
        .map(|e| e.tx.clone())
        .ok_or_else(&refused)?;
    let (client_side, server_side) = channel_pair();
    // Sent outside the registry lock: a full accept backlog must not block
    // the whole registry.
    tx.send(server_side).map_err(|_| refused())?;
    Ok(client_side)
}

/// Remove an endpoint from the registry by name (server shutdown).
/// Dropping the registered sender unblocks the listener's `accept` with
/// `Ok(None)`.
pub fn in_proc_unbind(name: &str) {
    registry().lock().unwrap().remove(name);
}

/// In-process listener half. Unbinds itself on drop (token-guarded, so a
/// name rebound by a newer listener in the meantime is left untouched).
pub struct InProcListener {
    name: String,
    token: u64,
    rx: Receiver<ChannelMsgStream>,
}

impl InProcListener {
    /// Register an endpoint. `None` picks a process-unique name.
    pub fn bind(name: Option<String>) -> Result<InProcListener> {
        let name = name.unwrap_or_else(unique_name);
        let token = next_token();
        let (tx, rx) = sync_channel(ACCEPT_BACKLOG);
        let mut reg = registry().lock().unwrap();
        if reg.contains_key(&name) {
            return Err(Error::InvalidArgument(format!(
                "in-proc endpoint {name:?} already bound"
            )));
        }
        reg.insert(name.clone(), RegisteredEndpoint { token, tx });
        Ok(InProcListener { name, token, rx })
    }

    pub fn name(&self) -> &str {
        &self.name
    }
}

impl Drop for InProcListener {
    fn drop(&mut self) {
        let mut reg = registry().lock().unwrap();
        if reg.get(&self.name).is_some_and(|e| e.token == self.token) {
            reg.remove(&self.name);
        }
    }
}

impl TransportListener for InProcListener {
    fn accept(&mut self) -> Result<Option<Box<dyn MsgStream>>> {
        match self.rx.recv() {
            Ok(stream) => Ok(Some(Box::new(stream))),
            // Every sender is gone: the endpoint was unbound.
            Err(_) => Ok(None),
        }
    }

    fn endpoint(&self) -> String {
        format!("{IN_PROC_SCHEME}{}", self.name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::chunk::{Chunk, Compression};
    use crate::core::tensor::Tensor;
    use std::sync::Arc;

    fn mk_chunk(key: u64) -> Arc<Chunk> {
        let steps = vec![vec![Tensor::from_f32(&[2], &[1.0, 2.0]).unwrap()]];
        Arc::new(Chunk::from_steps(key, 0, &steps, Compression::None).unwrap())
    }

    #[test]
    fn channel_pair_is_zero_copy() {
        // The defining property of the in-proc path: the receiver observes
        // the *same allocation* the sender handed in, not a decoded copy.
        let (mut a, mut b) = channel_pair();
        let chunk = mk_chunk(7);
        a.send(Message::InsertChunks {
            chunks: vec![chunk.clone()],
        })
        .unwrap();
        match b.recv().unwrap() {
            Message::InsertChunks { chunks } => {
                assert!(Arc::ptr_eq(&chunks[0], &chunk), "payload was copied");
            }
            other => panic!("wrong message {other:?}"),
        }
    }

    #[test]
    fn channel_pair_is_bidirectional() {
        let (mut a, mut b) = channel_pair();
        a.send(Message::InfoRequest { id: 1 }).unwrap();
        assert!(matches!(b.recv().unwrap(), Message::InfoRequest { id: 1 }));
        b.send(Message::Ack { id: 1, detail: "ok".into() }).unwrap();
        assert!(matches!(a.recv().unwrap(), Message::Ack { id: 1, .. }));
    }

    #[test]
    fn closed_peer_surfaces_as_io_error() {
        let (mut a, b) = channel_pair();
        drop(b);
        assert!(matches!(
            a.send(Message::InfoRequest { id: 1 }),
            Err(Error::Io(_))
        ));
        assert!(matches!(a.recv(), Err(Error::Io(_))));
    }

    #[test]
    fn channel_try_recv_reports_occupancy() {
        let (mut a, mut b) = channel_pair();
        assert!(b.try_recv().unwrap().is_none(), "empty = would-block");
        a.send(Message::InfoRequest { id: 3 }).unwrap();
        assert!(matches!(
            b.try_recv().unwrap(),
            Some(Message::InfoRequest { id: 3 })
        ));
        assert!(b.try_recv().unwrap().is_none());
        drop(a);
        assert!(b.try_recv().is_err(), "disconnect = peer closed");
    }

    #[test]
    fn channel_waker_fires_on_send_and_on_registration_backlog() {
        let (mut a, mut b) = channel_pair();
        let hits = Arc::new(std::sync::atomic::AtomicUsize::new(0));
        // A message delivered *before* registration must fire immediately.
        a.send(Message::InfoRequest { id: 1 }).unwrap();
        let h = hits.clone();
        b.set_ready_waker(Arc::new(move || {
            h.fetch_add(1, Ordering::SeqCst);
        }));
        assert_eq!(hits.load(Ordering::SeqCst), 1, "backlog fired at install");
        a.send(Message::InfoRequest { id: 2 }).unwrap();
        assert_eq!(hits.load(Ordering::SeqCst), 2, "send fired the waker");
        assert!(b.try_recv().unwrap().is_some());
        assert!(b.try_recv().unwrap().is_some());
    }

    #[test]
    fn dropping_a_channel_end_wakes_and_disconnects_the_peer() {
        // The event core relies on this: a vanished in-proc client must
        // produce one final readiness signal so the server observes the
        // disconnect instead of keeping the connection forever.
        let (a, mut b) = channel_pair();
        let hits = Arc::new(std::sync::atomic::AtomicUsize::new(0));
        let h = hits.clone();
        b.set_ready_waker(Arc::new(move || {
            h.fetch_add(1, Ordering::SeqCst);
        }));
        drop(a);
        assert_eq!(hits.load(Ordering::SeqCst), 1, "drop fired the waker");
        assert!(b.try_recv().is_err(), "disconnect visible to try_recv");
    }

    #[test]
    fn bind_dial_accept_roundtrip() {
        let mut listener = InProcListener::bind(Some("transport-test-1".into())).unwrap();
        let endpoint = listener.endpoint();
        assert_eq!(endpoint, format!("{IN_PROC_SCHEME}transport-test-1"));
        let mut client = dial(&endpoint).unwrap();
        let mut server = listener.accept().unwrap().expect("one connection");
        client.send(Message::InfoRequest { id: 9 }).unwrap();
        client.flush().unwrap();
        assert!(matches!(server.recv().unwrap(), Message::InfoRequest { id: 9 }));
        in_proc_unbind("transport-test-1");
    }

    #[test]
    fn unbind_unblocks_accept_and_refuses_dials() {
        let mut listener = InProcListener::bind(Some("transport-test-2".into())).unwrap();
        in_proc_unbind("transport-test-2");
        assert!(listener.accept().unwrap().is_none(), "accept must report closed");
        assert!(dial(&format!("{IN_PROC_SCHEME}transport-test-2")).is_err());
    }

    #[test]
    fn duplicate_bind_rejected() {
        let _l = InProcListener::bind(Some("transport-test-3".into())).unwrap();
        assert!(InProcListener::bind(Some("transport-test-3".into())).is_err());
    }

    #[test]
    fn drop_unbinds_and_allows_rebinding() {
        let listener = InProcListener::bind(Some("transport-test-4".into())).unwrap();
        drop(listener);
        assert!(dial("reverb://in-proc/transport-test-4").is_err());
        // The name is free again.
        let _again = InProcListener::bind(Some("transport-test-4".into())).unwrap();
    }

    #[test]
    fn stale_listener_drop_leaves_rebound_name_alone() {
        let stale = InProcListener::bind(Some("transport-test-5".into())).unwrap();
        // Server shutdown unbinds by name...
        in_proc_unbind("transport-test-5");
        // ...and a new server rebinds it before the old listener drops.
        let mut fresh = InProcListener::bind(Some("transport-test-5".into())).unwrap();
        drop(stale); // token mismatch: must NOT unbind the fresh endpoint
        let mut client = dial("reverb://in-proc/transport-test-5")
            .expect("fresh endpoint must survive the stale drop");
        let mut server = fresh.accept().unwrap().expect("one connection");
        client.send(Message::InfoRequest { id: 1 }).unwrap();
        assert!(matches!(server.recv().unwrap(), Message::InfoRequest { id: 1 }));
    }

    #[test]
    fn dial_unknown_endpoint_refused() {
        assert!(dial("reverb://in-proc/nowhere").is_err());
    }

    #[test]
    fn tcp_coalesced_frames_all_arrive_in_order() {
        // Many small frames queued before one flush: exactly one writev
        // burst on the wire, every frame delivered in order.
        let mut listener = TcpTransportListener::bind("127.0.0.1:0").unwrap();
        let endpoint = listener.endpoint();
        let mut client = dial(&endpoint).unwrap();
        let mut server = listener.accept().unwrap().expect("one connection");
        for id in 0..200u64 {
            client.send(Message::InfoRequest { id }).unwrap();
        }
        client.flush().unwrap();
        for id in 0..200u64 {
            match server.recv().unwrap() {
                Message::InfoRequest { id: got } => assert_eq!(got, id),
                other => panic!("wrong message {other:?}"),
            }
        }
    }

    #[test]
    fn tcp_send_queue_auto_flushes_past_threshold() {
        // Queued bytes beyond the threshold must hit the wire without an
        // explicit flush (bounded memory under deep pipelining). A reader
        // thread drains concurrently so the writer never deadlocks on
        // full socket buffers.
        let mut listener = TcpTransportListener::bind("127.0.0.1:0").unwrap();
        let endpoint = listener.endpoint();
        let mut client = dial(&endpoint).unwrap();
        let mut server = listener.accept().unwrap().expect("one connection");
        let reader = std::thread::spawn(move || {
            let mut keys = Vec::new();
            for _ in 0..8 {
                match server.recv().unwrap() {
                    Message::InsertChunks { chunks } => keys.push(chunks[0].key),
                    other => panic!("wrong message {other:?}"),
                }
            }
            keys
        });
        // ~80 kB per frame; 8 frames cross the 256 kB threshold twice.
        let steps =
            vec![vec![Tensor::from_f32(&[20_000], &vec![1.0f32; 20_000]).unwrap()]];
        for key in 0..8u64 {
            let chunk =
                Arc::new(Chunk::from_steps(key, 0, &steps, Compression::None).unwrap());
            client
                .send(Message::InsertChunks { chunks: vec![chunk] })
                .unwrap();
        }
        client.flush().unwrap();
        assert_eq!(reader.join().unwrap(), (0..8).collect::<Vec<u64>>());
    }

    #[test]
    fn tcp_scheme_prefix_is_accepted() {
        let mut listener = TcpTransportListener::bind("127.0.0.1:0").unwrap();
        let endpoint = listener.endpoint();
        assert!(endpoint.starts_with("tcp://"));
        let mut client = dial(&endpoint).unwrap();
        assert_eq!(client.transport(), "tcp");
        let mut server = listener.accept().unwrap().expect("one connection");
        client.send(Message::InfoRequest { id: 3 }).unwrap();
        client.flush().unwrap();
        assert!(matches!(server.recv().unwrap(), Message::InfoRequest { id: 3 }));
    }

    #[test]
    fn tcp_nonblocking_try_recv_would_block_then_delivers() {
        let mut listener = TcpTransportListener::bind("127.0.0.1:0").unwrap();
        let endpoint = listener.endpoint();
        let mut client = dial(&endpoint).unwrap();
        let mut server = listener.accept().unwrap().expect("one connection");
        server.set_nonblocking(true).unwrap();
        assert!(matches!(server.poll_source(), PollSource::Fd(fd) if fd >= 0));
        assert!(server.try_recv().unwrap().is_none(), "no input yet");
        client.send(Message::InfoRequest { id: 77 }).unwrap();
        client.flush().unwrap();
        // Loopback delivery is fast but asynchronous: poll briefly.
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        loop {
            match server.try_recv().unwrap() {
                Some(Message::InfoRequest { id }) => {
                    assert_eq!(id, 77);
                    break;
                }
                Some(other) => panic!("wrong message {other:?}"),
                None => {
                    assert!(std::time::Instant::now() < deadline, "frame never arrived");
                    std::thread::sleep(std::time::Duration::from_millis(1));
                }
            }
        }
    }

    #[cfg(unix)]
    #[test]
    fn unix_socket_roundtrip_and_cleanup() {
        let path = std::env::temp_dir().join(format!(
            "reverb_uds_transport_{}.sock",
            std::process::id()
        ));
        std::fs::remove_file(&path).ok();
        let mut listener = UnixTransportListener::bind(&path).unwrap();
        let endpoint = listener.endpoint();
        assert!(endpoint.starts_with(UNIX_SCHEME), "{endpoint}");
        let mut client = dial(&endpoint).unwrap();
        assert_eq!(client.transport(), "unix");
        let mut server = listener.accept().unwrap().expect("one connection");
        let chunk = mk_chunk(5);
        client
            .send(Message::InsertChunks { chunks: vec![chunk] })
            .unwrap();
        client.flush().unwrap();
        match server.recv().unwrap() {
            Message::InsertChunks { chunks } => assert_eq!(chunks[0].key, 5),
            other => panic!("wrong message {other:?}"),
        }
        server.send(Message::Ack { id: 1, detail: "ok".into() }).unwrap();
        server.flush().unwrap();
        assert!(matches!(client.recv().unwrap(), Message::Ack { id: 1, .. }));
        assert!(path.exists());
        drop(listener);
        assert!(!path.exists(), "socket file removed on drop");
    }

    #[cfg(unix)]
    #[test]
    fn unix_dial_missing_path_refused() {
        assert!(dial("reverb+unix:///tmp/reverb-no-such-socket.sock").is_err());
    }
}
