//! Pluggable transport (DESIGN.md §2): framed [`Message`] streams between
//! clients and servers, with two interchangeable backends.
//!
//! - **`tcp://host:port`** (bare `host:port` also accepted) — the original
//!   path: length-prefixed frames over a `TcpStream`, `Message`s encoded and
//!   decoded at each end.
//! - **`reverb://in-proc/<name>`** — a zero-copy in-process path: whole
//!   [`Message`] values move through channels (requests bounded for
//!   backpressure, replies unbounded for deadlock freedom — see
//!   [`CHANNEL_DEPTH`]), so chunk payloads (`Arc<Chunk>`) are *shared*,
//!   never serialized, copied, or pushed through a syscall. This is the
//!   default data plane for same-process actor/learner harnesses
//!   (`coordinator`), where the paper notes the throughput ceiling should
//!   live in the tables, not the transport.
//!
//! Both backends carry the identical protocol and error mapping: a closed
//! peer surfaces as [`Error::Io`], exactly like a TCP hang-up, so every
//! layer above (`Server`, `Client`, `Writer`, `Sampler`) is
//! transport-oblivious. The conformance suite in
//! `rust/tests/transport_conformance.rs` runs every black-box scenario
//! against both backends.

use crate::error::{Error, Result};
use crate::net::wire::Message;
use std::collections::HashMap;
use std::io::Write as _;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, sync_channel, Receiver, Sender, SyncSender};
use std::sync::{Mutex, OnceLock};

/// URL prefix of the in-process backend.
pub const IN_PROC_SCHEME: &str = "reverb://in-proc/";

/// Request-direction (client→server) messages buffered on an in-process
/// connection. Bounded so requests see the same backpressure a full TCP
/// socket buffer would. The reply direction is deliberately *unbounded*:
/// a server that can never block on replies always drains requests, which
/// rules out the request-full/reply-full deadlock for arbitrarily large
/// client pipelining windows; reply memory stays bounded by the client's
/// outstanding-request window for any client that reads its replies.
const CHANNEL_DEPTH: usize = 256;

/// Pending, not-yet-accepted connections per in-process listener.
const ACCEPT_BACKLOG: usize = 64;

/// A bidirectional, framed [`Message`] stream. `send` may buffer until
/// `flush`; `recv` blocks for the next message. A closed peer yields
/// [`Error::Io`] from `recv`/`send`, mirroring TCP semantics.
pub trait MsgStream: Send {
    fn send(&mut self, msg: Message) -> Result<()>;
    fn flush(&mut self) -> Result<()>;
    fn recv(&mut self) -> Result<Message>;
    /// Backend name for diagnostics ("tcp" / "in-proc").
    fn transport(&self) -> &'static str;
}

/// Server side of a transport: blocks for inbound connections.
pub trait TransportListener: Send {
    /// Next connection. `Ok(None)` means the listener was shut down.
    fn accept(&mut self) -> Result<Option<Box<dyn MsgStream>>>;
    /// The endpoint string clients dial to reach this listener.
    fn endpoint(&self) -> String;
}

/// Connect to an endpoint by URL. Dispatches on scheme:
/// `reverb://in-proc/<name>` (or `inproc://<name>`) to the channel backend,
/// `tcp://host:port` or bare `host:port` to TCP.
pub fn dial(addr: &str) -> Result<Box<dyn MsgStream>> {
    if let Some(name) = addr.strip_prefix(IN_PROC_SCHEME) {
        return Ok(Box::new(dial_in_proc(name)?));
    }
    if let Some(name) = addr.strip_prefix("inproc://") {
        return Ok(Box::new(dial_in_proc(name)?));
    }
    let hostport = addr.strip_prefix("tcp://").unwrap_or(addr);
    Ok(Box::new(TcpMsgStream::connect(hostport)?))
}

// ---------------------------------------------------------------------
// TCP backend
// ---------------------------------------------------------------------

/// Auto-flush threshold for queued outbound frames: matches the old
/// `BufWriter` capacity so memory stays bounded under deep pipelining.
const SEND_QUEUE_FLUSH_BYTES: usize = 256 * 1024;

/// Frame codec over one TCP connection with a vectored write path:
/// `send` encodes each frame into its own buffer and queues it; `flush`
/// hands the whole queue to `write_vectored`, so a pipelined burst of
/// small frames (chunk streams + item creations, ack trains) is one
/// `writev` syscall instead of one `write` per frame — with no
/// intermediate copy into a staging buffer.
pub struct TcpMsgStream {
    reader: std::io::BufReader<TcpStream>,
    stream: TcpStream,
    /// Encoded frames awaiting the next flush.
    pending: std::collections::VecDeque<Vec<u8>>,
    /// Bytes of `pending[0]` already written by a previous partial flush.
    head: usize,
    pending_bytes: usize,
}

impl TcpMsgStream {
    pub fn connect(addr: &str) -> Result<TcpMsgStream> {
        Self::from_stream(TcpStream::connect(addr)?)
    }

    pub fn from_stream(stream: TcpStream) -> Result<TcpMsgStream> {
        stream.set_nodelay(true)?;
        Ok(TcpMsgStream {
            reader: std::io::BufReader::with_capacity(256 * 1024, stream.try_clone()?),
            stream,
            pending: std::collections::VecDeque::new(),
            head: 0,
            pending_bytes: 0,
        })
    }

    /// Write every queued frame with as few `writev` calls as the kernel
    /// allows, handling partial writes across frame boundaries.
    fn flush_pending(&mut self) -> Result<()> {
        while !self.pending.is_empty() {
            let written = {
                let mut slices: Vec<std::io::IoSlice<'_>> =
                    Vec::with_capacity(self.pending.len());
                let mut iter = self.pending.iter();
                if let Some(first) = iter.next() {
                    slices.push(std::io::IoSlice::new(&first[self.head..]));
                }
                for buf in iter {
                    slices.push(std::io::IoSlice::new(buf));
                }
                // `Write for &TcpStream`: no mutable borrow of `self`
                // needed while `slices` borrows the queue.
                match (&self.stream).write_vectored(&slices) {
                    Ok(0) => {
                        return Err(Error::Io(std::io::Error::new(
                            std::io::ErrorKind::WriteZero,
                            "tcp peer stopped accepting frame bytes",
                        )))
                    }
                    Ok(n) => n,
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                    Err(e) => return Err(e.into()),
                }
            };
            self.consume_pending(written);
        }
        self.head = 0;
        self.pending_bytes = 0;
        Ok(())
    }

    /// Drop `n` written bytes off the front of the queue, keeping the
    /// auto-flush byte counter in sync even when a later `writev` in the
    /// same flush fails (the retry path must not see a stale count).
    fn consume_pending(&mut self, mut n: usize) {
        self.pending_bytes = self.pending_bytes.saturating_sub(n);
        while n > 0 {
            let first_remaining = self.pending[0].len() - self.head;
            if n >= first_remaining {
                n -= first_remaining;
                self.pending.pop_front();
                self.head = 0;
            } else {
                self.head += n;
                n = 0;
            }
        }
    }
}

impl Drop for TcpMsgStream {
    /// Best-effort flush of queued frames, restoring the flush-on-drop
    /// safety net the old `BufWriter` writer provided.
    fn drop(&mut self) {
        let _ = self.flush_pending();
    }
}

impl MsgStream for TcpMsgStream {
    fn send(&mut self, msg: Message) -> Result<()> {
        let frame = msg.encode_frame()?;
        self.pending_bytes += frame.len();
        self.pending.push_back(frame);
        if self.pending_bytes >= SEND_QUEUE_FLUSH_BYTES {
            self.flush_pending()?;
        }
        Ok(())
    }

    fn flush(&mut self) -> Result<()> {
        self.flush_pending()
    }

    fn recv(&mut self) -> Result<Message> {
        Message::read_frame(&mut self.reader)
    }

    fn transport(&self) -> &'static str {
        "tcp"
    }
}

/// TCP listener half.
pub struct TcpTransportListener {
    listener: TcpListener,
    local: SocketAddr,
}

impl TcpTransportListener {
    pub fn bind(addr: &str) -> Result<TcpTransportListener> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        Ok(TcpTransportListener { listener, local })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.local
    }
}

impl TransportListener for TcpTransportListener {
    fn accept(&mut self) -> Result<Option<Box<dyn MsgStream>>> {
        let (stream, _peer) = self.listener.accept()?;
        Ok(Some(Box::new(TcpMsgStream::from_stream(stream)?)))
    }

    fn endpoint(&self) -> String {
        format!("tcp://{}", self.local)
    }
}

// ---------------------------------------------------------------------
// In-process backend
// ---------------------------------------------------------------------

/// Sending half of an in-process direction: requests are bounded
/// (backpressure), replies unbounded (deadlock freedom) — see
/// [`CHANNEL_DEPTH`].
enum Tx {
    Bounded(SyncSender<Message>),
    Unbounded(Sender<Message>),
}

impl Tx {
    fn send(&self, msg: Message) -> std::result::Result<(), ()> {
        match self {
            Tx::Bounded(tx) => tx.send(msg).map_err(|_| ()),
            Tx::Unbounded(tx) => tx.send(msg).map_err(|_| ()),
        }
    }
}

/// One direction-pair of channels. Chunk payloads inside the `Message` are
/// `Arc<Chunk>` handles, so moving a message through the channel shares
/// the payload instead of copying it.
pub struct ChannelMsgStream {
    tx: Tx,
    rx: Receiver<Message>,
}

fn peer_closed() -> Error {
    Error::Io(std::io::Error::new(
        std::io::ErrorKind::BrokenPipe,
        "in-proc peer closed",
    ))
}

impl MsgStream for ChannelMsgStream {
    fn send(&mut self, msg: Message) -> Result<()> {
        self.tx.send(msg).map_err(|()| peer_closed())
    }

    fn flush(&mut self) -> Result<()> {
        Ok(())
    }

    fn recv(&mut self) -> Result<Message> {
        self.rx.recv().map_err(|_| peer_closed())
    }

    fn transport(&self) -> &'static str {
        "in-proc"
    }
}

/// Build a connected pair of in-process streams (client side, server
/// side). The client→server direction is bounded, server→client
/// unbounded — see [`CHANNEL_DEPTH`] for why.
pub fn channel_pair() -> (ChannelMsgStream, ChannelMsgStream) {
    let (tx_c2s, rx_c2s) = sync_channel(CHANNEL_DEPTH);
    let (tx_s2c, rx_s2c) = channel();
    (
        ChannelMsgStream {
            tx: Tx::Bounded(tx_c2s),
            rx: rx_s2c,
        },
        ChannelMsgStream {
            tx: Tx::Unbounded(tx_s2c),
            rx: rx_c2s,
        },
    )
}

/// A registered in-proc endpoint: the accept-queue sender plus a unique
/// token so a stale listener's `Drop` can never unbind a newer endpoint
/// that reused its name.
struct RegisteredEndpoint {
    token: u64,
    tx: SyncSender<ChannelMsgStream>,
}

/// Process-wide endpoint registry: in-proc listeners register here; `dial`
/// looks the name up and hands the listener the server half of a fresh
/// channel pair.
fn registry() -> &'static Mutex<HashMap<String, RegisteredEndpoint>> {
    static REGISTRY: OnceLock<Mutex<HashMap<String, RegisteredEndpoint>>> = OnceLock::new();
    REGISTRY.get_or_init(|| Mutex::new(HashMap::new()))
}

fn next_token() -> u64 {
    static NEXT: AtomicU64 = AtomicU64::new(0);
    NEXT.fetch_add(1, Ordering::Relaxed)
}

fn unique_name() -> String {
    format!("srv-{}-{}", std::process::id(), next_token())
}

fn dial_in_proc(name: &str) -> Result<ChannelMsgStream> {
    let refused = || {
        Error::Io(std::io::Error::new(
            std::io::ErrorKind::ConnectionRefused,
            format!("no in-proc server at {name:?}"),
        ))
    };
    let tx = registry()
        .lock()
        .unwrap()
        .get(name)
        .map(|e| e.tx.clone())
        .ok_or_else(&refused)?;
    let (client_side, server_side) = channel_pair();
    // Sent outside the registry lock: a full accept backlog must not block
    // the whole registry.
    tx.send(server_side).map_err(|_| refused())?;
    Ok(client_side)
}

/// Remove an endpoint from the registry by name (server shutdown).
/// Dropping the registered sender unblocks the listener's `accept` with
/// `Ok(None)`.
pub fn in_proc_unbind(name: &str) {
    registry().lock().unwrap().remove(name);
}

/// In-process listener half. Unbinds itself on drop (token-guarded, so a
/// name rebound by a newer listener in the meantime is left untouched).
pub struct InProcListener {
    name: String,
    token: u64,
    rx: Receiver<ChannelMsgStream>,
}

impl InProcListener {
    /// Register an endpoint. `None` picks a process-unique name.
    pub fn bind(name: Option<String>) -> Result<InProcListener> {
        let name = name.unwrap_or_else(unique_name);
        let token = next_token();
        let (tx, rx) = sync_channel(ACCEPT_BACKLOG);
        let mut reg = registry().lock().unwrap();
        if reg.contains_key(&name) {
            return Err(Error::InvalidArgument(format!(
                "in-proc endpoint {name:?} already bound"
            )));
        }
        reg.insert(name.clone(), RegisteredEndpoint { token, tx });
        Ok(InProcListener { name, token, rx })
    }

    pub fn name(&self) -> &str {
        &self.name
    }
}

impl Drop for InProcListener {
    fn drop(&mut self) {
        let mut reg = registry().lock().unwrap();
        if reg.get(&self.name).is_some_and(|e| e.token == self.token) {
            reg.remove(&self.name);
        }
    }
}

impl TransportListener for InProcListener {
    fn accept(&mut self) -> Result<Option<Box<dyn MsgStream>>> {
        match self.rx.recv() {
            Ok(stream) => Ok(Some(Box::new(stream))),
            // Every sender is gone: the endpoint was unbound.
            Err(_) => Ok(None),
        }
    }

    fn endpoint(&self) -> String {
        format!("{IN_PROC_SCHEME}{}", self.name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::chunk::{Chunk, Compression};
    use crate::core::tensor::Tensor;
    use std::sync::Arc;

    fn mk_chunk(key: u64) -> Arc<Chunk> {
        let steps = vec![vec![Tensor::from_f32(&[2], &[1.0, 2.0]).unwrap()]];
        Arc::new(Chunk::from_steps(key, 0, &steps, Compression::None).unwrap())
    }

    #[test]
    fn channel_pair_is_zero_copy() {
        // The defining property of the in-proc path: the receiver observes
        // the *same allocation* the sender handed in, not a decoded copy.
        let (mut a, mut b) = channel_pair();
        let chunk = mk_chunk(7);
        a.send(Message::InsertChunks {
            chunks: vec![chunk.clone()],
        })
        .unwrap();
        match b.recv().unwrap() {
            Message::InsertChunks { chunks } => {
                assert!(Arc::ptr_eq(&chunks[0], &chunk), "payload was copied");
            }
            other => panic!("wrong message {other:?}"),
        }
    }

    #[test]
    fn channel_pair_is_bidirectional() {
        let (mut a, mut b) = channel_pair();
        a.send(Message::InfoRequest { id: 1 }).unwrap();
        assert!(matches!(b.recv().unwrap(), Message::InfoRequest { id: 1 }));
        b.send(Message::Ack { id: 1, detail: "ok".into() }).unwrap();
        assert!(matches!(a.recv().unwrap(), Message::Ack { id: 1, .. }));
    }

    #[test]
    fn closed_peer_surfaces_as_io_error() {
        let (mut a, b) = channel_pair();
        drop(b);
        assert!(matches!(
            a.send(Message::InfoRequest { id: 1 }),
            Err(Error::Io(_))
        ));
        assert!(matches!(a.recv(), Err(Error::Io(_))));
    }

    #[test]
    fn bind_dial_accept_roundtrip() {
        let mut listener = InProcListener::bind(Some("transport-test-1".into())).unwrap();
        let endpoint = listener.endpoint();
        assert_eq!(endpoint, format!("{IN_PROC_SCHEME}transport-test-1"));
        let mut client = dial(&endpoint).unwrap();
        let mut server = listener.accept().unwrap().expect("one connection");
        client.send(Message::InfoRequest { id: 9 }).unwrap();
        client.flush().unwrap();
        assert!(matches!(server.recv().unwrap(), Message::InfoRequest { id: 9 }));
        in_proc_unbind("transport-test-1");
    }

    #[test]
    fn unbind_unblocks_accept_and_refuses_dials() {
        let mut listener = InProcListener::bind(Some("transport-test-2".into())).unwrap();
        in_proc_unbind("transport-test-2");
        assert!(listener.accept().unwrap().is_none(), "accept must report closed");
        assert!(dial(&format!("{IN_PROC_SCHEME}transport-test-2")).is_err());
    }

    #[test]
    fn duplicate_bind_rejected() {
        let _l = InProcListener::bind(Some("transport-test-3".into())).unwrap();
        assert!(InProcListener::bind(Some("transport-test-3".into())).is_err());
    }

    #[test]
    fn drop_unbinds_and_allows_rebinding() {
        let listener = InProcListener::bind(Some("transport-test-4".into())).unwrap();
        drop(listener);
        assert!(dial("reverb://in-proc/transport-test-4").is_err());
        // The name is free again.
        let _again = InProcListener::bind(Some("transport-test-4".into())).unwrap();
    }

    #[test]
    fn stale_listener_drop_leaves_rebound_name_alone() {
        let stale = InProcListener::bind(Some("transport-test-5".into())).unwrap();
        // Server shutdown unbinds by name...
        in_proc_unbind("transport-test-5");
        // ...and a new server rebinds it before the old listener drops.
        let mut fresh = InProcListener::bind(Some("transport-test-5".into())).unwrap();
        drop(stale); // token mismatch: must NOT unbind the fresh endpoint
        let mut client = dial("reverb://in-proc/transport-test-5")
            .expect("fresh endpoint must survive the stale drop");
        let mut server = fresh.accept().unwrap().expect("one connection");
        client.send(Message::InfoRequest { id: 1 }).unwrap();
        assert!(matches!(server.recv().unwrap(), Message::InfoRequest { id: 1 }));
    }

    #[test]
    fn dial_unknown_endpoint_refused() {
        assert!(dial("reverb://in-proc/nowhere").is_err());
    }

    #[test]
    fn tcp_coalesced_frames_all_arrive_in_order() {
        // Many small frames queued before one flush: exactly one writev
        // burst on the wire, every frame delivered in order.
        let mut listener = TcpTransportListener::bind("127.0.0.1:0").unwrap();
        let endpoint = listener.endpoint();
        let mut client = dial(&endpoint).unwrap();
        let mut server = listener.accept().unwrap().expect("one connection");
        for id in 0..200u64 {
            client.send(Message::InfoRequest { id }).unwrap();
        }
        client.flush().unwrap();
        for id in 0..200u64 {
            match server.recv().unwrap() {
                Message::InfoRequest { id: got } => assert_eq!(got, id),
                other => panic!("wrong message {other:?}"),
            }
        }
    }

    #[test]
    fn tcp_send_queue_auto_flushes_past_threshold() {
        // Queued bytes beyond the threshold must hit the wire without an
        // explicit flush (bounded memory under deep pipelining). A reader
        // thread drains concurrently so the writer never deadlocks on
        // full socket buffers.
        let mut listener = TcpTransportListener::bind("127.0.0.1:0").unwrap();
        let endpoint = listener.endpoint();
        let mut client = dial(&endpoint).unwrap();
        let mut server = listener.accept().unwrap().expect("one connection");
        let reader = std::thread::spawn(move || {
            let mut keys = Vec::new();
            for _ in 0..8 {
                match server.recv().unwrap() {
                    Message::InsertChunks { chunks } => keys.push(chunks[0].key),
                    other => panic!("wrong message {other:?}"),
                }
            }
            keys
        });
        // ~80 kB per frame; 8 frames cross the 256 kB threshold twice.
        let steps =
            vec![vec![Tensor::from_f32(&[20_000], &vec![1.0f32; 20_000]).unwrap()]];
        for key in 0..8u64 {
            let chunk =
                Arc::new(Chunk::from_steps(key, 0, &steps, Compression::None).unwrap());
            client
                .send(Message::InsertChunks { chunks: vec![chunk] })
                .unwrap();
        }
        client.flush().unwrap();
        assert_eq!(reader.join().unwrap(), (0..8).collect::<Vec<u64>>());
    }

    #[test]
    fn tcp_scheme_prefix_is_accepted() {
        let mut listener = TcpTransportListener::bind("127.0.0.1:0").unwrap();
        let endpoint = listener.endpoint();
        assert!(endpoint.starts_with("tcp://"));
        let mut client = dial(&endpoint).unwrap();
        assert_eq!(client.transport(), "tcp");
        let mut server = listener.accept().unwrap().expect("one connection");
        client.send(Message::InfoRequest { id: 3 }).unwrap();
        client.flush().unwrap();
        assert!(matches!(server.recv().unwrap(), Message::InfoRequest { id: 3 }));
    }
}
