//! A small readiness poller for the event-driven service core
//! (DESIGN.md §11): level-triggered `ppoll(2)` over the registered
//! connection descriptors, with **one-shot interest** semantics (a fired
//! interest is cleared until the owner re-arms it, so a slow worker never
//! makes the poll loop spin on a still-readable socket).
//!
//! The offline crate set has no `libc`/`mio`, so the syscall is issued
//! directly (inline asm on Linux x86_64/aarch64). Elsewhere a portable
//! fallback reports every armed descriptor as ready on a short tick —
//! correct (workers discover the truth via `WouldBlock`) at the cost of
//! some idle CPU; real deployments are Linux.
//!
//! Wakeups: the poller sleeps inside the syscall, so registration changes
//! and timer arrivals interrupt it by writing one byte to a loopback
//! socket pair that is always part of the polled set (the classic
//! self-pipe trick, built from `std` TCP because `pipe(2)` is not exposed
//! without libc).

use crate::error::Result;
use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Mutex;
use std::time::Duration;

/// One registered descriptor's armed interests.
struct Entry {
    fd: i32,
    read: bool,
    write: bool,
}

/// Readiness poller over raw descriptors. Tokens are caller-chosen `u64`s
/// (the event core uses connection ids).
pub struct Poller {
    entries: Mutex<HashMap<u64, Entry>>,
    /// Write end of the wakeup pair (any thread may poke it).
    wake_tx: Mutex<TcpStream>,
    /// Read end, drained by the polling thread.
    wake_rx: Mutex<TcpStream>,
    wake_fd: i32,
}

impl Poller {
    pub fn new() -> Result<Poller> {
        // Loopback socket pair standing in for pipe(2).
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let wake_tx = TcpStream::connect(listener.local_addr()?)?;
        let (wake_rx, _) = listener.accept()?;
        wake_tx.set_nodelay(true)?;
        wake_tx.set_nonblocking(true)?;
        wake_rx.set_nonblocking(true)?;
        let wake_fd = raw_fd(&wake_rx);
        Ok(Poller {
            entries: Mutex::new(HashMap::new()),
            wake_tx: Mutex::new(wake_tx),
            wake_rx: Mutex::new(wake_rx),
            wake_fd,
        })
    }

    /// Register a descriptor under `token` with no interests armed.
    pub fn register(&self, token: u64, fd: i32) {
        self.entries.lock().unwrap().insert(
            token,
            Entry {
                fd,
                read: false,
                write: false,
            },
        );
    }

    /// Forget a token (connection closed). The caller still owns the fd.
    pub fn deregister(&self, token: u64) {
        self.entries.lock().unwrap().remove(&token);
        self.wake();
    }

    /// Arm read interest (one-shot: cleared when reported).
    pub fn arm_read(&self, token: u64) {
        if let Some(e) = self.entries.lock().unwrap().get_mut(&token) {
            e.read = true;
        }
        self.wake();
    }

    /// Arm write interest (one-shot: cleared when reported).
    pub fn arm_write(&self, token: u64) {
        if let Some(e) = self.entries.lock().unwrap().get_mut(&token) {
            e.write = true;
        }
        self.wake();
    }

    /// Interrupt an in-flight [`Poller::poll`].
    pub fn wake(&self) {
        // WouldBlock = the wake buffer already holds unconsumed pokes; the
        // sleeping poll will return regardless.
        let _ = self.wake_tx.lock().unwrap().write(&[1u8]);
    }

    /// Wait up to `timeout` for readiness. Returns the tokens whose
    /// descriptors fired (their fired interests are now disarmed — the
    /// owner re-arms after servicing). Error/hangup conditions are
    /// reported like readiness: the owner's next read/write discovers the
    /// close.
    pub fn poll(&self, timeout: Duration) -> Vec<u64> {
        // Snapshot under the lock, syscall outside it (registration must
        // not block for a full poll interval).
        let mut fds: Vec<sys::PollFd> = Vec::new();
        let mut tokens: Vec<u64> = Vec::new();
        {
            let entries = self.entries.lock().unwrap();
            fds.reserve(entries.len() + 1);
            tokens.reserve(entries.len());
            fds.push(sys::PollFd {
                fd: self.wake_fd,
                events: sys::POLLIN,
                revents: 0,
            });
            for (&token, e) in entries.iter() {
                if e.fd < 0 || (!e.read && !e.write) {
                    continue;
                }
                let mut ev = 0i16;
                if e.read {
                    ev |= sys::POLLIN;
                }
                if e.write {
                    ev |= sys::POLLOUT;
                }
                fds.push(sys::PollFd {
                    fd: e.fd,
                    events: ev,
                    revents: 0,
                });
                tokens.push(token);
            }
        }

        let n = sys::poll(&mut fds, timeout);
        let mut fired = Vec::new();
        if n <= 0 {
            return fired;
        }
        debug_assert_eq!(fds[0].fd, self.wake_fd, "wake slot must stay first");
        if fds[0].revents != 0 {
            // Drain accumulated wakeup bytes.
            let mut sink = [0u8; 256];
            let mut rx = self.wake_rx.lock().unwrap();
            while matches!(rx.read(&mut sink), Ok(n) if n > 0) {}
        }
        let mut entries = self.entries.lock().unwrap();
        for (pfd, &token) in fds[1..].iter().zip(tokens.iter()) {
            if pfd.revents == 0 {
                continue;
            }
            fired.push(token);
            // One-shot: clear what we polled for on this round. Hangup and
            // error conditions disarm both directions — the service pass
            // will hit the close and deregister.
            if let Some(e) = entries.get_mut(&token) {
                let err = pfd.revents & (sys::POLLERR | sys::POLLHUP | sys::POLLNVAL) != 0;
                if err || pfd.events & sys::POLLIN != 0 {
                    e.read = false;
                }
                if err || pfd.events & sys::POLLOUT != 0 {
                    e.write = false;
                }
            }
        }
        fired
    }
}

#[cfg(unix)]
fn raw_fd(s: &TcpStream) -> i32 {
    std::os::unix::io::AsRawFd::as_raw_fd(s)
}

#[cfg(not(unix))]
fn raw_fd(_s: &TcpStream) -> i32 {
    -1
}

/// Best-effort raise of the process's open-file soft limit to at least
/// `want` (capped at the hard limit). High-connection-count benches and
/// soak tests call this; failure is non-fatal (the caller simply accepts
/// fewer connections).
pub fn ensure_fd_capacity(want: u64) {
    sys::raise_nofile(want);
}

// ---------------------------------------------------------------------
// Platform layer: ppoll(2) / prlimit64(2) without libc
// ---------------------------------------------------------------------

#[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
mod sys {
    use std::time::Duration;

    /// `struct pollfd` (POSIX layout).
    #[repr(C)]
    pub struct PollFd {
        pub fd: i32,
        pub events: i16,
        pub revents: i16,
    }

    pub const POLLIN: i16 = 0x001;
    pub const POLLOUT: i16 = 0x004;
    pub const POLLERR: i16 = 0x008;
    pub const POLLHUP: i16 = 0x010;
    pub const POLLNVAL: i16 = 0x020;

    #[repr(C)]
    struct Timespec {
        sec: i64,
        nsec: i64,
    }

    #[repr(C)]
    struct RLimit64 {
        cur: u64,
        max: u64,
    }

    #[cfg(target_arch = "x86_64")]
    const SYS_PPOLL: usize = 271;
    #[cfg(target_arch = "x86_64")]
    const SYS_PRLIMIT64: usize = 302;
    #[cfg(target_arch = "aarch64")]
    const SYS_PPOLL: usize = 73;
    #[cfg(target_arch = "aarch64")]
    const SYS_PRLIMIT64: usize = 261;

    const EINTR: isize = -4;
    const RLIMIT_NOFILE: usize = 7;

    #[cfg(target_arch = "x86_64")]
    unsafe fn syscall5(n: usize, a1: usize, a2: usize, a3: usize, a4: usize, a5: usize) -> isize {
        let ret: isize;
        core::arch::asm!(
            "syscall",
            inlateout("rax") n as isize => ret,
            in("rdi") a1,
            in("rsi") a2,
            in("rdx") a3,
            in("r10") a4,
            in("r8") a5,
            lateout("rcx") _,
            lateout("r11") _,
            options(nostack),
        );
        ret
    }

    #[cfg(target_arch = "aarch64")]
    unsafe fn syscall5(n: usize, a1: usize, a2: usize, a3: usize, a4: usize, a5: usize) -> isize {
        let ret: isize;
        core::arch::asm!(
            "svc 0",
            in("x8") n as isize,
            inlateout("x0") a1 as isize => ret,
            in("x1") a2,
            in("x2") a3,
            in("x3") a4,
            in("x4") a5,
            options(nostack),
        );
        ret
    }

    /// `ppoll(fds, nfds, timeout, NULL, sizeof(sigset_t))`; returns the
    /// number of ready descriptors, 0 on timeout or EINTR, and never
    /// panics (other errors also map to 0 — the caller's loop retries).
    pub fn poll(fds: &mut [PollFd], timeout: Duration) -> isize {
        let ts = Timespec {
            sec: timeout.as_secs().min(i64::MAX as u64) as i64,
            nsec: timeout.subsec_nanos() as i64,
        };
        let ret = unsafe {
            syscall5(
                SYS_PPOLL,
                fds.as_mut_ptr() as usize,
                fds.len(),
                (&ts as *const Timespec) as usize,
                0, // sigmask: NULL (keep the caller's signal mask)
                8, // sigsetsize: sizeof(kernel sigset_t)
            )
        };
        if ret == EINTR {
            return 0;
        }
        ret.max(0)
    }

    /// Raise `RLIMIT_NOFILE`'s soft limit toward `want` (capped at hard).
    pub fn raise_nofile(want: u64) {
        let mut old = RLimit64 { cur: 0, max: 0 };
        let got = unsafe {
            syscall5(
                SYS_PRLIMIT64,
                0, // self
                RLIMIT_NOFILE,
                0, // no new limit: read only
                (&mut old as *mut RLimit64) as usize,
                0,
            )
        };
        if got != 0 || old.cur >= want {
            return;
        }
        let new = RLimit64 {
            cur: want.min(old.max),
            max: old.max,
        };
        unsafe {
            syscall5(
                SYS_PRLIMIT64,
                0,
                RLIMIT_NOFILE,
                (&new as *const RLimit64) as usize,
                0,
                0,
            );
        }
    }
}

/// Portable fallback: no readiness syscall available, so report every
/// armed descriptor as ready on a short tick. Workers discover the truth
/// via `WouldBlock`; correctness is preserved at the cost of idle CPU.
#[cfg(not(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64"))))]
mod sys {
    use std::time::Duration;

    #[repr(C)]
    pub struct PollFd {
        pub fd: i32,
        pub events: i16,
        pub revents: i16,
    }

    pub const POLLIN: i16 = 0x001;
    pub const POLLOUT: i16 = 0x004;
    pub const POLLERR: i16 = 0x008;
    pub const POLLHUP: i16 = 0x010;
    pub const POLLNVAL: i16 = 0x020;

    pub fn poll(fds: &mut [PollFd], timeout: Duration) -> isize {
        std::thread::sleep(timeout.min(Duration::from_millis(1)));
        let mut n = 0isize;
        for f in fds.iter_mut().skip(1) {
            // skip the wake slot; report every armed, valid fd as ready
            f.revents = if f.fd >= 0 { f.events } else { 0 };
            n += 1;
        }
        n
    }

    pub fn raise_nofile(_want: u64) {}
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Instant;

    fn tcp_pair() -> (TcpStream, TcpStream) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let a = TcpStream::connect(listener.local_addr().unwrap()).unwrap();
        let (b, _) = listener.accept().unwrap();
        (a, b)
    }

    #[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
    #[test]
    fn readable_fd_fires_and_interest_is_one_shot() {
        let poller = Poller::new().unwrap();
        let (mut writer, reader) = tcp_pair();
        reader.set_nonblocking(true).unwrap();
        poller.register(7, raw_fd(&reader));
        poller.arm_read(7);

        // Nothing readable yet: a short poll reports nothing (wake pokes
        // from arm_read may cause early returns, so drain a few rounds).
        let deadline = Instant::now() + Duration::from_millis(200);
        let mut fired = Vec::new();
        while Instant::now() < deadline {
            fired = poller.poll(Duration::from_millis(10));
            if !fired.is_empty() {
                break;
            }
        }
        assert!(fired.is_empty(), "fired without data: {fired:?}");

        writer.write_all(&[9u8]).unwrap();
        writer.flush().unwrap();
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            let fired = poller.poll(Duration::from_millis(20));
            if fired.contains(&7) {
                break;
            }
            assert!(Instant::now() < deadline, "readable fd never reported");
        }

        // One-shot: without re-arming, the still-readable fd stays silent.
        for _ in 0..5 {
            assert!(
                !poller.poll(Duration::from_millis(5)).contains(&7),
                "one-shot interest fired twice"
            );
        }

        // Re-arm → fires again.
        poller.arm_read(7);
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            if poller.poll(Duration::from_millis(20)).contains(&7) {
                break;
            }
            assert!(Instant::now() < deadline, "re-armed fd never reported");
        }
    }

    #[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
    #[test]
    fn hangup_reports_readiness() {
        let poller = Poller::new().unwrap();
        let (writer, reader) = tcp_pair();
        reader.set_nonblocking(true).unwrap();
        poller.register(3, raw_fd(&reader));
        poller.arm_read(3);
        drop(writer);
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            if poller.poll(Duration::from_millis(20)).contains(&3) {
                break;
            }
            assert!(Instant::now() < deadline, "hangup never reported");
        }
    }

    #[test]
    fn wake_interrupts_a_long_poll() {
        let poller = std::sync::Arc::new(Poller::new().unwrap());
        let p2 = poller.clone();
        let waker = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            p2.wake();
        });
        let start = Instant::now();
        // No registered fds: only the wake channel can end this early.
        poller.poll(Duration::from_secs(10));
        assert!(
            start.elapsed() < Duration::from_secs(5),
            "wake did not interrupt the poll"
        );
        waker.join().unwrap();
    }

    #[test]
    fn deregistered_token_never_fires() {
        let poller = Poller::new().unwrap();
        let (mut writer, reader) = tcp_pair();
        reader.set_nonblocking(true).unwrap();
        poller.register(1, raw_fd(&reader));
        poller.arm_read(1);
        poller.deregister(1);
        writer.write_all(&[1u8]).unwrap();
        for _ in 0..5 {
            assert!(poller.poll(Duration::from_millis(5)).is_empty());
        }
    }
}
