//! Prometheus text exposition for the `/metrics` endpoint (DESIGN.md
//! §12): a hand-rolled renderer over the observability accessors the
//! tables, gate, journal, and event core already expose — no HTTP or
//! metrics crate, just the text format (version 0.0.4).
//!
//! Every value is read through the same lock-free atomics (or short
//! shard-lock holds) the data plane uses, so a scrape never stalls
//! inserts or samples. Non-finite gauges are rendered as the exposition
//! format's `+Inf` / `-Inf` / `NaN` literals — the `MinSize` limiter
//! legitimately reports infinite corridor bounds.

use crate::net::event::EventShared;
use crate::net::server::ServerInner;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Cap on an accepted scrape's request head; anything longer is dropped
/// (a scrape request is a handful of lines).
pub(crate) const MAX_HTTP_HEAD: usize = 8192;

/// True once `buf` holds a complete HTTP request head. Bare-`\n`
/// separators are tolerated for hand-written test clients.
pub(crate) fn head_complete(buf: &[u8]) -> bool {
    buf.windows(4).any(|w| w == b"\r\n\r\n") || buf.windows(2).any(|w| w == b"\n\n")
}

/// Build the full HTTP response for a scrape request head: `GET
/// /metrics` gets the rendered exposition, anything else a small error.
/// Responses are always `Connection: close` — scrapes are one-shot.
pub(crate) fn http_response(
    head: &[u8],
    inner: &ServerInner,
    event: Option<&EventShared>,
) -> Vec<u8> {
    let line = head.split(|&b| b == b'\n').next().unwrap_or(b"");
    let line = String::from_utf8_lossy(line);
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("");
    let target = parts.next().unwrap_or("");
    let path = target.split('?').next().unwrap_or("");
    let (status, content_type, body) = if method != "GET" {
        (
            "405 Method Not Allowed",
            "text/plain; charset=utf-8",
            "method not allowed\n".to_string(),
        )
    } else if !authorized(head, inner.metrics_token.as_deref()) {
        // 401 before the path check: an unauthenticated scraper learns
        // nothing about what paths exist.
        return format!(
            "HTTP/1.1 401 Unauthorized\r\nContent-Type: text/plain; charset=utf-8\r\n\
             Content-Length: 13\r\nWWW-Authenticate: Bearer\r\nConnection: close\r\n\r\n\
             unauthorized\n"
        )
        .into_bytes();
    } else if path == "/metrics" {
        (
            "200 OK",
            "text/plain; version=0.0.4; charset=utf-8",
            render_prometheus(inner, event),
        )
    } else if path == "/trace" {
        // Flight-recorder dump (DESIGN.md §15): the retained span ring as
        // Chrome trace-event JSON — load it in chrome://tracing or
        // Perfetto. Same bearer auth as /metrics (checked above).
        (
            "200 OK",
            "application/json; charset=utf-8",
            crate::net::trace::recorder().render_chrome_json(),
        )
    } else {
        (
            "404 Not Found",
            "text/plain; charset=utf-8",
            "not found (try /metrics)\n".to_string(),
        )
    };
    format!(
        "HTTP/1.1 {status}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )
    .into_bytes()
}

/// Scrape auth (DESIGN.md §14): when the server was built with
/// `metrics_token`, every request must carry `Authorization: Bearer
/// <token>`. With no token configured, every request is authorized —
/// the loopback-only default keeps its zero-config scrape.
fn authorized(head: &[u8], token: Option<&str>) -> bool {
    let Some(token) = token else { return true };
    for line in head.split(|&b| b == b'\n') {
        let line = String::from_utf8_lossy(line);
        let line = line.trim_end_matches('\r');
        let Some((name, value)) = line.split_once(':') else {
            continue;
        };
        if name.trim().eq_ignore_ascii_case("authorization") {
            let value = value.trim();
            let Some(bearer) = value.strip_prefix("Bearer ") else {
                return false;
            };
            return bearer.trim() == token;
        }
    }
    false
}

/// A sample value in exposition syntax (`+Inf`/`-Inf`/`NaN` for the
/// non-finite cases — never the bare Rust `inf` Display form).
fn fmt_value(v: f64) -> String {
    if v.is_nan() {
        "NaN".to_string()
    } else if v == f64::INFINITY {
        "+Inf".to_string()
    } else if v == f64::NEG_INFINITY {
        "-Inf".to_string()
    } else {
        format!("{v}")
    }
}

/// Escape a label value per the exposition format.
fn escape_label(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

/// Upper bounds (seconds) of the service-time histogram buckets. The
/// ladder spans in-proc dispatch (~µs) through corridor-blocked waits
/// (seconds); `+Inf` is implicit. Chosen once for every table so
/// exposition families stay mergeable across tables.
pub(crate) const LATENCY_BUCKETS: [f64; 12] = [
    0.00005, 0.0001, 0.00025, 0.0005, 0.001, 0.0025, 0.005, 0.01, 0.05, 0.25, 1.0, 5.0,
];

/// A lock-free fixed-bucket latency histogram. `record` takes one atomic
/// increment per observation (buckets are stored non-cumulative and
/// cumulated at render time), so the data plane never serializes on the
/// exporter. Sums are tracked in integer microseconds to stay atomic.
#[derive(Default)]
pub(crate) struct LatencyHistogram {
    buckets: [AtomicU64; LATENCY_BUCKETS.len()],
    sum_micros: AtomicU64,
    count: AtomicU64,
}

impl LatencyHistogram {
    pub(crate) fn record(&self, elapsed: Duration) {
        let secs = elapsed.as_secs_f64();
        if let Some(i) = LATENCY_BUCKETS.iter().position(|le| secs <= *le) {
            self.buckets[i].fetch_add(1, Ordering::Relaxed);
        }
        self.sum_micros
            .fetch_add(elapsed.as_micros() as u64, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    /// Observations recorded so far (tests / diagnostics).
    pub(crate) fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Append the `_bucket`/`_sum`/`_count` samples of one labelled
    /// series. Buckets are emitted cumulative per the exposition format,
    /// with the implicit `+Inf` bucket equal to `_count`; `le` is appended
    /// after the caller's labels.
    fn render_into(&self, e: &mut Expo, name: &str, labels: &[(&str, &str)]) {
        let bucket_name = format!("{name}_bucket");
        let mut cumulative = 0u64;
        for (i, le) in LATENCY_BUCKETS.iter().enumerate() {
            cumulative += self.buckets[i].load(Ordering::Relaxed);
            let le = fmt_value(*le);
            let mut with_le = labels.to_vec();
            with_le.push(("le", &le));
            e.sample(&bucket_name, &with_le, cumulative as f64);
        }
        let count = self.count.load(Ordering::Relaxed);
        let mut with_le = labels.to_vec();
        with_le.push(("le", "+Inf"));
        e.sample(&bucket_name, &with_le, count as f64);
        let sum = self.sum_micros.load(Ordering::Relaxed) as f64 / 1e6;
        e.sample(&format!("{name}_sum"), labels, sum);
        e.sample(&format!("{name}_count"), labels, count as f64);
    }
}

/// Per-table service-time histograms, fed from the dispatch paths of both
/// service models (threaded: around the blocking handler; event: dispatch
/// to reply, spanning parked time).
#[derive(Default)]
pub(crate) struct TableLatency {
    pub(crate) insert: LatencyHistogram,
    pub(crate) sample: LatencyHistogram,
}

/// Exposition buffer: `family` opens a `# HELP`/`# TYPE` block, `sample`
/// appends one labelled value to the open family.
struct Expo {
    out: String,
}

impl Expo {
    fn family(&mut self, name: &str, kind: &str, help: &str) {
        self.out.push_str("# HELP ");
        self.out.push_str(name);
        self.out.push(' ');
        self.out.push_str(help);
        self.out.push_str("\n# TYPE ");
        self.out.push_str(name);
        self.out.push(' ');
        self.out.push_str(kind);
        self.out.push('\n');
    }

    fn sample(&mut self, name: &str, labels: &[(&str, &str)], value: f64) {
        self.out.push_str(name);
        if !labels.is_empty() {
            self.out.push('{');
            for (i, (k, v)) in labels.iter().enumerate() {
                if i > 0 {
                    self.out.push(',');
                }
                self.out.push_str(k);
                self.out.push_str("=\"");
                self.out.push_str(&escape_label(v));
                self.out.push('"');
            }
            self.out.push('}');
        }
        self.out.push(' ');
        self.out.push_str(&fmt_value(value));
        self.out.push('\n');
    }
}

/// Render the full exposition. `event` is `Some` under the event-driven
/// service model, adding per-worker and connection-count families.
pub(crate) fn render_prometheus(inner: &ServerInner, event: Option<&EventShared>) -> String {
    let mut e = Expo {
        out: String::with_capacity(4096),
    };

    // Snapshot per-table state once; each exposition family then groups
    // all its samples under a single TYPE header as the format requires.
    let tables: Vec<_> = inner
        .table_order
        .iter()
        .map(|t| {
            (
                t.name().to_string(),
                t.info(),
                t.rate_limiter_bounds(),
                t.samples_per_insert(),
                t.waiter_depths(),
                t.rearm_hook_depths(),
                t.watcher_depth(),
                t.shard_stats(),
            )
        })
        .collect();

    e.family("reverb_table_size", "gauge", "Items currently in the table.");
    for (name, info, ..) in &tables {
        e.sample("reverb_table_size", &[("table", name)], info.size as f64);
    }
    e.family("reverb_table_max_size", "gauge", "Configured capacity before eviction.");
    for (name, info, ..) in &tables {
        e.sample("reverb_table_max_size", &[("table", name)], info.max_size as f64);
    }
    e.family("reverb_table_inserts_total", "counter", "Items inserted since start.");
    for (name, info, ..) in &tables {
        e.sample("reverb_table_inserts_total", &[("table", name)], info.inserts as f64);
    }
    e.family("reverb_table_samples_total", "counter", "Items sampled since start.");
    for (name, info, ..) in &tables {
        e.sample("reverb_table_samples_total", &[("table", name)], info.samples as f64);
    }
    e.family(
        "reverb_table_rate_limited_inserts_total",
        "counter",
        "Insert episodes blocked by the rate-limiter corridor.",
    );
    for (name, info, ..) in &tables {
        e.sample(
            "reverb_table_rate_limited_inserts_total",
            &[("table", name)],
            info.rate_limited_inserts as f64,
        );
    }
    e.family(
        "reverb_table_rate_limited_samples_total",
        "counter",
        "Sample episodes blocked by the rate-limiter corridor.",
    );
    for (name, info, ..) in &tables {
        e.sample(
            "reverb_table_rate_limited_samples_total",
            &[("table", name)],
            info.rate_limited_samples as f64,
        );
    }

    e.family(
        "reverb_rate_limiter_diff",
        "gauge",
        "Corridor cursor: inserts x samples_per_insert - samples.",
    );
    for (name, info, ..) in &tables {
        e.sample("reverb_rate_limiter_diff", &[("table", name)], info.diff);
    }
    e.family(
        "reverb_rate_limiter_min_diff",
        "gauge",
        "Lower corridor bound (samples block below).",
    );
    for (name, _, bounds, ..) in &tables {
        e.sample("reverb_rate_limiter_min_diff", &[("table", name)], bounds.0);
    }
    e.family(
        "reverb_rate_limiter_max_diff",
        "gauge",
        "Upper corridor bound (inserts block above).",
    );
    for (name, _, bounds, ..) in &tables {
        e.sample("reverb_rate_limiter_max_diff", &[("table", name)], bounds.1);
    }
    e.family(
        "reverb_rate_limiter_samples_per_insert",
        "gauge",
        "Target sampling rate per insert.",
    );
    for (name, _, _, spi, ..) in &tables {
        e.sample("reverb_rate_limiter_samples_per_insert", &[("table", name)], *spi);
    }

    e.family("reverb_table_insert_waiters", "gauge", "Threads blocked in the insert corridor.");
    for (name, _, _, _, waiters, ..) in &tables {
        e.sample("reverb_table_insert_waiters", &[("table", name)], waiters.0 as f64);
    }
    e.family("reverb_table_sample_waiters", "gauge", "Threads blocked in the sample corridor.");
    for (name, _, _, _, waiters, ..) in &tables {
        e.sample("reverb_table_sample_waiters", &[("table", name)], waiters.1 as f64);
    }
    e.family(
        "reverb_table_insert_rearm_hooks",
        "gauge",
        "Parked event-core inserts awaiting a corridor wakeup.",
    );
    for (name, _, _, _, _, hooks, ..) in &tables {
        e.sample("reverb_table_insert_rearm_hooks", &[("table", name)], hooks.0 as f64);
    }
    e.family(
        "reverb_table_sample_rearm_hooks",
        "gauge",
        "Parked event-core samples awaiting a corridor wakeup.",
    );
    for (name, _, _, _, _, hooks, ..) in &tables {
        e.sample("reverb_table_sample_rearm_hooks", &[("table", name)], hooks.1 as f64);
    }
    e.family("reverb_table_watchers", "gauge", "Live watch-stream subscriptions on the table.");
    for (name, _, _, _, _, _, watchers, _) in &tables {
        e.sample("reverb_table_watchers", &[("table", name)], *watchers as f64);
    }

    e.family("reverb_shard_mass", "gauge", "Total priority mass per shard.");
    for (name, _, _, _, _, _, _, shards) in &tables {
        for (i, (mass, _)) in shards.iter().enumerate() {
            let shard = i.to_string();
            e.sample("reverb_shard_mass", &[("table", name), ("shard", &shard)], *mass);
        }
    }
    e.family("reverb_shard_items", "gauge", "Item count per shard.");
    for (name, _, _, _, _, _, _, shards) in &tables {
        for (i, (_, count)) in shards.iter().enumerate() {
            let shard = i.to_string();
            e.sample("reverb_shard_items", &[("table", name), ("shard", &shard)], *count as f64);
        }
    }

    e.family(
        "reverb_table_insert_latency_seconds",
        "histogram",
        "Insert (CreateItem) service time from dispatch to reply, including parked/corridor time.",
    );
    for t in &inner.table_order {
        if let Some(tl) = inner.latency.get(t.name()) {
            tl.insert.render_into(
                &mut e,
                "reverb_table_insert_latency_seconds",
                &[("table", t.name())],
            );
        }
    }
    e.family(
        "reverb_table_sample_latency_seconds",
        "histogram",
        "Sample service time from dispatch to reply, including parked/corridor time.",
    );
    for t in &inner.table_order {
        if let Some(tl) = inner.latency.get(t.name()) {
            tl.sample.render_into(
                &mut e,
                "reverb_table_sample_latency_seconds",
                &[("table", t.name())],
            );
        }
    }

    e.family(
        "reverb_stage_duration_seconds",
        "histogram",
        "Per-request stage timings (DESIGN.md §15); table \"_server\" holds connection-scoped stages.",
    );
    let stage_rows: Vec<&str> = inner
        .table_order
        .iter()
        .map(|t| t.name())
        .chain(std::iter::once("_server"))
        .collect();
    for name in stage_rows {
        if let Some(row) = inner.stages.get(name) {
            for stage in crate::net::trace::SERVER_STAGES {
                let idx = stage.server_index().expect("server stage");
                row[idx].render_into(
                    &mut e,
                    "reverb_stage_duration_seconds",
                    &[("table", name), ("stage", stage.name())],
                );
            }
        }
    }

    e.family(
        "reverb_table_sampled_to_inserted_ratio",
        "gauge",
        "Lifetime samples / inserts per table (NaN before the first insert).",
    );
    for (name, info, ..) in &tables {
        let ratio = if info.inserts == 0 {
            f64::NAN
        } else {
            info.samples as f64 / info.inserts as f64
        };
        e.sample("reverb_table_sampled_to_inserted_ratio", &[("table", name)], ratio);
    }

    e.family(
        "reverb_table_item_age_steps",
        "histogram",
        "Item age at sample time, in inserts landed since the item (power-of-two buckets).",
    );
    for t in &inner.table_order {
        let (buckets, count, sum) = t.age_histogram().snapshot();
        let name = t.name();
        let mut cumulative = 0u64;
        for (i, n) in buckets.iter().take(crate::core::table::AGE_BUCKETS).enumerate() {
            cumulative += n;
            let le = crate::core::table::AgeHistogram::bound(i).to_string();
            e.sample(
                "reverb_table_item_age_steps_bucket",
                &[("table", name), ("le", &le)],
                cumulative as f64,
            );
        }
        e.sample(
            "reverb_table_item_age_steps_bucket",
            &[("table", name), ("le", "+Inf")],
            count as f64,
        );
        e.sample("reverb_table_item_age_steps_sum", &[("table", name)], sum as f64);
        e.sample("reverb_table_item_age_steps_count", &[("table", name)], count as f64);
    }

    e.family(
        "reverb_gate_last_pause_seconds",
        "gauge",
        "Duration of the most recent checkpoint gate pause.",
    );
    e.sample("reverb_gate_last_pause_seconds", &[], inner.gate.last_pause().as_secs_f64());
    e.family(
        "reverb_gate_in_flight",
        "gauge",
        "Table operations currently inside the checkpoint gate.",
    );
    e.sample("reverb_gate_in_flight", &[], inner.gate.in_flight() as f64);

    e.family(
        "reverb_persist_journal_lag_bytes",
        "gauge",
        "Approximate bytes sealed to the persist writer but not yet on disk.",
    );
    e.sample("reverb_persist_journal_lag_bytes", &[], inner.journal_lag_bytes() as f64);

    // Chunk-store tiering (DESIGN.md §16): one stats snapshot feeds every
    // family so hot/cold gauges are mutually consistent.
    let cs = inner.store.stats();
    e.family(
        "reverb_chunkstore_hot_chunks",
        "gauge",
        "Live chunks resident in memory (hot tier).",
    );
    e.sample("reverb_chunkstore_hot_chunks", &[], cs.hot_chunks as f64);
    e.family(
        "reverb_chunkstore_hot_bytes",
        "gauge",
        "Encoded payload bytes resident in memory (hot tier).",
    );
    e.sample("reverb_chunkstore_hot_bytes", &[], cs.hot_bytes as f64);
    e.family(
        "reverb_chunkstore_cold_chunks",
        "gauge",
        "Live chunks whose payload lives only in a cold spill file.",
    );
    e.sample("reverb_chunkstore_cold_chunks", &[], cs.cold_chunks as f64);
    e.family(
        "reverb_chunkstore_cold_bytes",
        "gauge",
        "On-disk bytes of live cold records, framing included.",
    );
    e.sample("reverb_chunkstore_cold_bytes", &[], cs.cold_bytes as f64);
    e.family(
        "reverb_chunkstore_cold_files",
        "gauge",
        "Cold spill files currently on disk.",
    );
    e.sample("reverb_chunkstore_cold_files", &[], cs.cold_files as f64);
    e.family(
        "reverb_chunkstore_demotions_total",
        "counter",
        "Hot-to-cold chunk spills since start.",
    );
    e.sample("reverb_chunkstore_demotions_total", &[], cs.demotions as f64);
    e.family(
        "reverb_chunkstore_rehydrations_total",
        "counter",
        "Cold-to-hot chunk promotions since start.",
    );
    e.sample("reverb_chunkstore_rehydrations_total", &[], cs.rehydrations as f64);
    e.family(
        "reverb_chunkstore_swept_entries_total",
        "counter",
        "Dead weak key-map entries removed by maintenance sweeps.",
    );
    e.sample("reverb_chunkstore_swept_entries_total", &[], cs.swept_entries as f64);
    e.family(
        "reverb_chunkstore_compactions_total",
        "counter",
        "Cold-file compactions since start.",
    );
    e.sample("reverb_chunkstore_compactions_total", &[], cs.compactions as f64);
    e.family(
        "reverb_chunkstore_rehydration_latency_seconds",
        "histogram",
        "Time to re-read and decode one chunk from the cold tier.",
    );
    inner
        .store
        .rehydration_latency()
        .render_into(&mut e, "reverb_chunkstore_rehydration_latency_seconds", &[]);

    if let Some(shared) = event {
        e.family(
            "reverb_connections",
            "gauge",
            "Connections live on the event core (including scrapes).",
        );
        e.sample("reverb_connections", &[], shared.live_conns() as f64);
        e.family("reverb_worker_dispatches_total", "counter", "Service passes run per worker.");
        let stats = shared.worker_stats();
        for (i, w) in stats.iter().enumerate() {
            let worker = i.to_string();
            e.sample(
                "reverb_worker_dispatches_total",
                &[("worker", &worker)],
                w.dispatches.load(std::sync::atomic::Ordering::Relaxed) as f64,
            );
        }
        e.family("reverb_worker_frames_total", "counter", "Frames dispatched per worker.");
        for (i, w) in stats.iter().enumerate() {
            let worker = i.to_string();
            e.sample(
                "reverb_worker_frames_total",
                &[("worker", &worker)],
                w.frames.load(std::sync::atomic::Ordering::Relaxed) as f64,
            );
        }
    }

    e.out
}

/// Read one HTTP request head from a blocking socket (shared by the
/// server's threaded scrape fallback and the client-side fabric
/// exporter). `None` means the head was oversized and the connection
/// should just be dropped.
pub(crate) fn read_request_head(
    sock: &mut std::net::TcpStream,
) -> std::io::Result<Option<Vec<u8>>> {
    use std::io::Read;
    let mut head = Vec::new();
    let mut buf = [0u8; 1024];
    while !head_complete(&head) {
        if head.len() > MAX_HTTP_HEAD {
            return Ok(None);
        }
        let n = sock.read(&mut buf)?;
        if n == 0 {
            break;
        }
        head.extend_from_slice(&buf[..n]);
    }
    Ok(Some(head))
}

/// Minimal one-shot responder for a standalone plain-text exposition
/// endpoint (the client-side fabric scrape listener, which has no
/// [`ServerInner`] to route against): `GET /metrics` → 200 with
/// `body()`, wrong method → 405, anything else → 404. Always
/// `Connection: close`.
pub(crate) fn plain_scrape_response(head: &[u8], body: impl FnOnce() -> String) -> Vec<u8> {
    let line = head.split(|&b| b == b'\n').next().unwrap_or(b"");
    let line = String::from_utf8_lossy(line);
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("");
    let target = parts.next().unwrap_or("");
    let path = target.split('?').next().unwrap_or("");
    let (status, content_type, body) = if method != "GET" {
        (
            "405 Method Not Allowed",
            "text/plain; charset=utf-8",
            "method not allowed\n".to_string(),
        )
    } else if path == "/metrics" {
        ("200 OK", "text/plain; version=0.0.4; charset=utf-8", body())
    } else {
        (
            "404 Not Found",
            "text/plain; charset=utf-8",
            "not found (try /metrics)\n".to_string(),
        )
    };
    format!(
        "HTTP/1.1 {status}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )
    .into_bytes()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn head_complete_detects_terminators() {
        assert!(!head_complete(b"GET /metrics HTTP/1.1\r\n"));
        assert!(head_complete(b"GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n"));
        assert!(head_complete(b"GET /metrics HTTP/1.1\n\n"));
    }

    #[test]
    fn values_render_exposition_literals() {
        assert_eq!(fmt_value(f64::INFINITY), "+Inf");
        assert_eq!(fmt_value(f64::NEG_INFINITY), "-Inf");
        assert_eq!(fmt_value(f64::NAN), "NaN");
        assert_eq!(fmt_value(1.5), "1.5");
        assert_eq!(fmt_value(3.0), "3");
    }

    #[test]
    fn labels_escape_specials() {
        assert_eq!(escape_label("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
    }

    #[test]
    fn bearer_auth_matches_token() {
        let head = b"GET /metrics HTTP/1.1\r\nAuthorization: Bearer s3cret\r\n\r\n";
        // No token configured: everything is authorized.
        assert!(authorized(head, None));
        assert!(authorized(b"GET /metrics HTTP/1.1\r\n\r\n", None));
        // Token configured: exact bearer match required.
        assert!(authorized(head, Some("s3cret")));
        assert!(!authorized(head, Some("other")));
        assert!(!authorized(b"GET /metrics HTTP/1.1\r\n\r\n", Some("s3cret")));
        // Header name is case-insensitive; Basic scheme is refused.
        assert!(authorized(
            b"GET / HTTP/1.1\r\nauthorization:   Bearer s3cret\r\n\r\n",
            Some("s3cret")
        ));
        assert!(!authorized(
            b"GET / HTTP/1.1\r\nAuthorization: Basic s3cret\r\n\r\n",
            Some("s3cret")
        ));
    }

    #[test]
    fn histogram_buckets_render_cumulative() {
        let h = LatencyHistogram::default();
        h.record(Duration::from_micros(80)); // <= 0.0001
        h.record(Duration::from_micros(80));
        h.record(Duration::from_millis(2)); // <= 0.0025
        h.record(Duration::from_secs(60)); // beyond the ladder: +Inf only
        let mut e = Expo { out: String::new() };
        h.render_into(&mut e, "x_seconds", &[("table", "t")]);
        let lines: Vec<&str> = e.out.lines().collect();
        assert_eq!(lines.len(), LATENCY_BUCKETS.len() + 3);
        assert!(lines.contains(&"x_seconds_bucket{table=\"t\",le=\"0.0001\"} 2"));
        assert!(lines.contains(&"x_seconds_bucket{table=\"t\",le=\"0.0025\"} 3"));
        // The last finite bucket still excludes the 60 s outlier...
        assert!(lines.contains(&"x_seconds_bucket{table=\"t\",le=\"5\"} 3"));
        // ...which only the +Inf bucket (== _count) captures.
        assert!(lines.contains(&"x_seconds_bucket{table=\"t\",le=\"+Inf\"} 4"));
        assert!(lines.contains(&"x_seconds_count{table=\"t\"} 4"));
        assert_eq!(h.count(), 4);
        let sum_line = lines.iter().find(|l| l.starts_with("x_seconds_sum")).unwrap();
        let v: f64 = sum_line.rsplit(' ').next().unwrap().parse().unwrap();
        assert!((v - 60.00216).abs() < 1e-6, "sum was {v}");
    }
}
