//! Networking: the wire protocol (gRPC analogue), the pluggable transport
//! layer (TCP + Unix sockets + zero-copy in-process), the readiness
//! poller, the event-driven service core, the server, the `/metrics`
//! exposition, and the checkpoint gate.

pub mod event;
pub mod gate;
pub(crate) mod metrics;
pub mod poller;
pub mod server;
pub mod trace;
pub mod transport;
pub mod wire;

pub use server::{PersistMode, Server, ServerBuilder, ServiceModel};
pub use transport::{dial, MsgStream, PollSource, TransportListener, IN_PROC_SCHEME, UNIX_SCHEME};
