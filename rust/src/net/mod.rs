//! Networking: the wire protocol (gRPC analogue), the pluggable transport
//! layer (TCP + zero-copy in-process), the server, and the checkpoint gate.

pub mod gate;
pub mod server;
pub mod transport;
pub mod wire;

pub use server::{PersistMode, Server, ServerBuilder};
pub use transport::{dial, MsgStream, TransportListener, IN_PROC_SCHEME};
