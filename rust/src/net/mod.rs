//! Networking: the wire protocol (gRPC analogue), the server, and the
//! checkpoint gate.

pub mod gate;
pub mod server;
pub mod wire;

pub use server::{Server, ServerBuilder};
