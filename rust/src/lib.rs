//! # Reverb — a framework for experience replay
//!
//! A Rust reproduction of *"Reverb: A Framework For Experience Replay"*
//! (Cassirer et al., 2021): an efficient, flexible data storage and
//! transport system for reinforcement learning, with a streaming
//! client/server over a pluggable transport (TCP or a zero-copy in-process
//! channel — see `net::transport`), pluggable selectors, SPI rate
//! limiting, chunked and compressed storage, checkpointing, and sharding —
//! plus a three-layer JAX/Pallas learner stack executed through PJRT (see
//! `runtime`; the PJRT backend itself is gated, DESIGN.md §5).

pub mod client;
pub mod coordinator;
pub mod core;
pub mod error;
pub mod io;
pub mod net;
pub mod persist;
pub mod rl;
pub mod runtime;
pub mod util;

pub use crate::core::chunk::{Chunk, ChunkBuilder, Compression};
pub use crate::core::chunk_store::ChunkStore;
pub use crate::core::item::{ChunkSlice, Item, SampledItem, TrajectoryColumn};
pub use crate::core::rate_limiter::{RateLimiter, RateLimiterConfig};
pub use crate::core::selector::SelectorConfig;
pub use crate::core::table::{
    default_shard_count, AgeHistogram, ShardedTable, Table, TableConfig, TableInfo, AGE_BUCKETS,
};
pub use crate::core::tensor::{DType, Signature, Tensor, TensorSpec};
pub use crate::client::{
    AdminRequest, Client, ClientPool, Completion, Dataset, Fabric, FabricOptions, Pipeline,
    Sample, Sampler, SamplerOptions, StandbyConfig, StepRef, Trajectory, TrajectoryWriter,
    TrajectoryWriterOptions, Watch, Writer, WriterOptions,
};
pub use crate::net::trace::TraceContext;
pub use crate::net::wire::{BatchResult, PriorityUpdateOp};
pub use crate::error::{Error, Result};
pub use crate::net::event::default_service_threads;
pub use crate::net::{PersistMode, Server, ServerBuilder, ServiceModel};
pub use crate::persist::{PersistConfig, Persister};
