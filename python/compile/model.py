"""Layer 2: the experience *consumer* — a double-DQN learner in JAX.

This is the compute graph that Reverb feeds: a Q-network MLP built from the
Layer-1 Pallas kernels (`kernels.mlp`), double-DQN TD targets from
`kernels.td`, per-example Huber loss weighted by the prioritized sampler's
importance weights, and an inline Adam optimizer. Both entry points
(`infer`, `train_step`) take and return *flat tuples of arrays* so the
AOT-lowered HLO has a stable calling convention for the Rust runtime.

Python runs only at build time: `aot.py` lowers these functions once to
HLO text; the Rust coordinator executes them through PJRT forever after.
"""

import jax
import jax.numpy as jnp

from compile.kernels import mlp, td

# ---------------------------------------------------------------------------
# Network configuration
# ---------------------------------------------------------------------------


def layer_sizes(obs_dim, hidden, num_actions):
    """[(in, out)] per layer for an MLP obs -> hidden... -> actions."""
    dims = [obs_dim, *hidden, num_actions]
    return list(zip(dims[:-1], dims[1:]))


def init_params(rng_key, obs_dim, hidden, num_actions):
    """He-initialized parameters as the flat list [w0, b0, w1, b1, ...]."""
    flat = []
    for d_in, d_out in layer_sizes(obs_dim, hidden, num_actions):
        rng_key, sub = jax.random.split(rng_key)
        scale = jnp.sqrt(2.0 / d_in)
        flat.append(jax.random.normal(sub, (d_in, d_out), jnp.float32) * scale)
        flat.append(jnp.zeros((d_out,), jnp.float32))
    return flat


def unflatten(flat_params):
    """Flat [w0, b0, w1, b1, ...] -> [(w, b), ...]."""
    assert len(flat_params) % 2 == 0
    return [(flat_params[i], flat_params[i + 1]) for i in range(0, len(flat_params), 2)]


# ---------------------------------------------------------------------------
# Entry points (flat signatures for AOT)
# ---------------------------------------------------------------------------


def q_values(flat_params, obs):
    """[B, A] Q-values via the Pallas MLP."""
    return mlp.mlp_forward(unflatten(flat_params), obs)


def infer(*args):
    """AOT entry: `infer(w0, b0, ..., obs) -> (q_values,)`."""
    flat_params, obs = list(args[:-1]), args[-1]
    return (q_values(flat_params, obs),)


def _train_step_impl(
    online, target, m, v, step, obs, actions, rewards, discounts, next_obs, weights,
    *, gamma, lr, beta1, beta2, eps, huber_delta,
):
    def loss_fn(params):
        q = q_values(params, obs)  # [B, A]
        q_chosen = jnp.take_along_axis(q, actions[:, None], axis=-1)[:, 0]
        # The fused TD-target kernel consumes only next-state values and
        # batch scalars; wrap in stop_gradient for explicitness.
        q_next_online = jax.lax.stop_gradient(q_values(params, next_obs))
        q_next_target = jax.lax.stop_gradient(q_values(target, next_obs))
        tgt = jax.lax.stop_gradient(
            td.td_targets(q_next_online, q_next_target, rewards, discounts, gamma=gamma)
        )
        td_err = q_chosen - tgt
        abs_err = jnp.abs(td_err)
        quad = jnp.minimum(abs_err, huber_delta)
        lin = abs_err - quad
        loss_vec = weights * (0.5 * quad * quad + huber_delta * lin)
        return jnp.mean(loss_vec), abs_err

    (loss, priorities), grads = jax.value_and_grad(loss_fn, has_aux=True)(online)

    # Inline Adam (bias-corrected learning rate form).
    step = step + 1.0
    lr_t = lr * jnp.sqrt(1.0 - beta2**step) / (1.0 - beta1**step)
    new_online, new_m, new_v = [], [], []
    for p, g, mi, vi in zip(online, grads, m, v):
        mi = beta1 * mi + (1.0 - beta1) * g
        vi = beta2 * vi + (1.0 - beta2) * (g * g)
        p = p - lr_t * mi / (jnp.sqrt(vi) + eps)
        new_online.append(p)
        new_m.append(mi)
        new_v.append(vi)

    return new_online, new_m, new_v, step, loss, priorities


def make_train_step(
    num_layers, gamma=0.99, lr=1e-3, beta1=0.9, beta2=0.999, eps=1e-8, huber_delta=1.0
):
    """Build the flat-signature AOT train step for a `num_layers`-layer MLP.

    Flat signature (`P = 2 * num_layers` parameter arrays):
      inputs:  online[P], target[P], m[P], v[P], step (f32 scalar),
               obs [B,O] f32, actions [B] i32, rewards [B] f32,
               discounts [B] f32, next_obs [B,O] f32, weights [B] f32
      outputs: new_online[P], new_m[P], new_v[P], new_step, loss, priorities
    """
    P = 2 * num_layers

    def train_step(*args):
        assert len(args) == 4 * P + 7, f"expected {4 * P + 7} args, got {len(args)}"
        online = list(args[0:P])
        target = list(args[P : 2 * P])
        m = list(args[2 * P : 3 * P])
        v = list(args[3 * P : 4 * P])
        (step, obs, actions, rewards, discounts, next_obs, weights) = args[4 * P :]
        new_online, new_m, new_v, new_step, loss, priorities = _train_step_impl(
            online, target, m, v, step, obs, actions, rewards, discounts, next_obs,
            weights, gamma=gamma, lr=lr, beta1=beta1, beta2=beta2, eps=eps,
            huber_delta=huber_delta,
        )
        return (*new_online, *new_m, *new_v, new_step, loss, priorities)

    return train_step


# ---------------------------------------------------------------------------
# Pure-jnp reference learner (oracle for python/tests/test_model.py)
# ---------------------------------------------------------------------------


def q_values_ref(flat_params, obs):
    from compile.kernels import ref

    h = obs
    layers = unflatten(flat_params)
    for i, (w, b) in enumerate(layers):
        h = ref.linear_relu_ref(h, w, b, apply_relu=i < len(layers) - 1)
    return h


def train_step_ref(
    online, target, m, v, step, obs, actions, rewards, discounts, next_obs, weights,
    *, gamma, lr=1e-3, beta1=0.9, beta2=0.999, eps=1e-8, huber_delta=1.0,
):
    """Same computation as `_train_step_impl` with no Pallas anywhere."""
    from compile.kernels import ref

    def loss_fn(params):
        q = q_values_ref(params, obs)
        q_chosen = jnp.take_along_axis(q, actions[:, None], axis=-1)[:, 0]
        q_next_online = jax.lax.stop_gradient(q_values_ref(params, next_obs))
        q_next_target = jax.lax.stop_gradient(q_values_ref(target, next_obs))
        tgt = jax.lax.stop_gradient(
            ref.td_targets_ref(q_next_online, q_next_target, rewards, discounts, gamma=gamma)
        )
        td_err = q_chosen - tgt
        loss_vec = weights * ref.huber_ref(td_err, delta=huber_delta)
        return jnp.mean(loss_vec), jnp.abs(td_err)

    (loss, priorities), grads = jax.value_and_grad(loss_fn, has_aux=True)(online)
    step = step + 1.0
    lr_t = lr * jnp.sqrt(1.0 - beta2**step) / (1.0 - beta1**step)
    new_online, new_m, new_v = [], [], []
    for p, g, mi, vi in zip(online, grads, m, v):
        mi = beta1 * mi + (1.0 - beta1) * g
        vi = beta2 * vi + (1.0 - beta2) * (g * g)
        new_online.append(p - lr_t * mi / (jnp.sqrt(vi) + eps))
        new_m.append(mi)
        new_v.append(vi)
    return new_online, new_m, new_v, step, loss, priorities
