"""AOT lowering: JAX → HLO *text* → `artifacts/` for the Rust runtime.

The interchange format is HLO text, NOT serialized `HloModuleProto` — jax ≥
0.5 emits protos with 64-bit instruction ids that the `xla` crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Outputs (written to --out-dir, default ../artifacts):
  qnet_infer.hlo.txt   — infer(params..., obs) -> (q,)
  qnet_train.hlo.txt   — train_step(online..., target..., m..., v..., step,
                          batch...) -> (new state..., loss, priorities)
  meta.txt             — key/value manifest the Rust runtime parses
                          (network sizes, batch, hyperparams, layer shapes)

Python runs ONLY here, at build time (`make artifacts`); the Rust binary is
self-contained afterwards.
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple calling conv)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def param_specs(obs_dim, hidden, num_actions):
    """ShapeDtypeStructs of the flat parameter list."""
    specs = []
    for d_in, d_out in model.layer_sizes(obs_dim, hidden, num_actions):
        specs.append(jax.ShapeDtypeStruct((d_in, d_out), jnp.float32))
        specs.append(jax.ShapeDtypeStruct((d_out,), jnp.float32))
    return specs


def lower_all(obs_dim, hidden, num_actions, batch, infer_batch, gamma, lr):
    params = param_specs(obs_dim, hidden, num_actions)
    num_layers = len(params) // 2

    obs_b = jax.ShapeDtypeStruct((batch, obs_dim), jnp.float32)
    obs_i = jax.ShapeDtypeStruct((infer_batch, obs_dim), jnp.float32)
    vec = jax.ShapeDtypeStruct((batch,), jnp.float32)
    ivec = jax.ShapeDtypeStruct((batch,), jnp.int32)
    scalar = jax.ShapeDtypeStruct((), jnp.float32)

    infer_lowered = jax.jit(model.infer).lower(*params, obs_i)

    train_step = model.make_train_step(num_layers, gamma=gamma, lr=lr)
    train_args = (
        *params, *params, *params, *params,  # online, target, m, v
        scalar, obs_b, ivec, vec, vec, obs_b, vec,
    )
    train_lowered = jax.jit(train_step).lower(*train_args)
    return infer_lowered, train_lowered


def write_meta(path, *, obs_dim, hidden, num_actions, batch, infer_batch, gamma, lr):
    lines = [
        f"obs_dim {obs_dim}",
        f"num_actions {num_actions}",
        f"hidden {' '.join(str(h) for h in hidden)}",
        f"batch {batch}",
        f"infer_batch {infer_batch}",
        f"gamma {gamma}",
        f"lr {lr}",
    ]
    for i, (d_in, d_out) in enumerate(model.layer_sizes(obs_dim, hidden, num_actions)):
        lines.append(f"layer{i} {d_in} {d_out}")
    with open(path, "w") as f:
        f.write("\n".join(lines) + "\n")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--out-dir",
        default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"),
    )
    ap.add_argument("--obs-dim", type=int, default=4, help="CartPole observation dim")
    ap.add_argument("--num-actions", type=int, default=2)
    ap.add_argument("--hidden", type=int, nargs="+", default=[64, 64])
    ap.add_argument("--batch", type=int, default=64, help="train batch size")
    ap.add_argument("--infer-batch", type=int, default=1, help="actor inference batch")
    ap.add_argument("--gamma", type=float, default=0.99)
    ap.add_argument("--lr", type=float, default=1e-3)
    # Back-compat with the scaffold Makefile's `--out artifacts/model.hlo.txt`.
    ap.add_argument("--out", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args()

    out_dir = os.path.dirname(args.out) if args.out else args.out_dir
    os.makedirs(out_dir, exist_ok=True)

    infer_lowered, train_lowered = lower_all(
        args.obs_dim, args.hidden, args.num_actions, args.batch, args.infer_batch,
        args.gamma, args.lr,
    )

    for name, lowered in [("qnet_infer", infer_lowered), ("qnet_train", train_lowered)]:
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")

    meta_path = os.path.join(out_dir, "meta.txt")
    write_meta(
        meta_path,
        obs_dim=args.obs_dim, hidden=args.hidden, num_actions=args.num_actions,
        batch=args.batch, infer_batch=args.infer_batch, gamma=args.gamma, lr=args.lr,
    )
    print(f"wrote {meta_path}")


if __name__ == "__main__":
    main()
