"""Layer 1: fused linear(+bias)(+ReLU) Pallas kernels, forward and backward.

TPU-style formulation (DESIGN.md §Hardware-Adaptation): the [B, IN] x
[IN, OUT] matmul is tiled into VMEM-sized blocks via BlockSpec — grid over
(B/BM, OUT/BN), with the full IN (contraction) axis resident per tile, f32
accumulation on the MXU, and the bias-add + ReLU fused into the epilogue so
activations never round-trip to HBM between the matmul and the
nonlinearity.

The layer is exposed through `jax.custom_vjp`: the backward pass reuses the
same tiled Pallas matmul for dX = G @ Wᵀ and dW = Xᵀ @ G (ReLU mask applied
to G first; dB is a cheap reduction XLA fuses into the mask multiply).

On this image Pallas runs with `interpret=True` (the CPU PJRT plugin cannot
execute Mosaic custom-calls); the BlockSpec structure is what carries over
to real TPU. VMEM budgeting for the default tiles is in EXPERIMENTS.md
§Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes: multiples of the 128x128 MXU systolic array / 8x128
# VPU lanes. A (128, K<=1024, 128) f32 tile set costs
#   x: 128*1024*4 = 512 KiB, w: 1024*128*4 = 512 KiB, o: 128*128*4 = 64 KiB
# ~= 1.1 MiB of VMEM — comfortably inside the ~16 MiB/core budget with
# double buffering.
BLOCK_B = 128
BLOCK_OUT = 128


def _affine_kernel(x_ref, w_ref, b_ref, o_ref, *, apply_relu):
    """One (BM, BN) output tile: full-K matmul + bias + optional ReLU."""
    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    acc = jnp.dot(x, w, preferred_element_type=jnp.float32)
    acc = acc + b_ref[...].astype(jnp.float32)[None, :]
    if apply_relu:
        acc = jnp.maximum(acc, 0.0)
    o_ref[...] = acc


def _affine_call(x, w, b, *, apply_relu, block_b, block_out):
    batch, d_in = x.shape
    d_in_w, d_out = w.shape
    assert d_in == d_in_w, f"contraction mismatch {d_in} vs {d_in_w}"
    assert b.shape == (d_out,)
    bm = min(block_b, batch)
    bn = min(block_out, d_out)
    grid = (pl.cdiv(batch, bm), pl.cdiv(d_out, bn))
    return pl.pallas_call(
        functools.partial(_affine_kernel, apply_relu=apply_relu),
        out_shape=jax.ShapeDtypeStruct((batch, d_out), jnp.float32),
        grid=grid,
        in_specs=[
            # Activations: tile the batch axis, full K resident.
            pl.BlockSpec((bm, d_in), lambda i, j: (i, 0)),
            # Weights: tile the OUT axis, full K resident.
            pl.BlockSpec((d_in, bn), lambda i, j: (0, j)),
            # Bias: tile matching the OUT tile.
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x, w, b)


def _matmul_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = jnp.dot(
        a_ref[...].astype(jnp.float32),
        b_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )


def matmul(a, b, *, block_m=BLOCK_B, block_n=BLOCK_OUT):
    """Tiled Pallas matmul `a @ b` (used by the backward pass)."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    bm = min(block_m, m)
    bn = min(block_n, n)
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn))
    return pl.pallas_call(
        _matmul_kernel,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        interpret=True,
    )(a, b)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def linear_relu(x, w, b, apply_relu=True):
    """Fused `relu(x @ w + b)` (or affine only) with a Pallas fwd + bwd.

    Args:
      x: [B, IN] activations (f32 or bf16).
      w: [IN, OUT] weights.
      b: [OUT] bias.
      apply_relu: fuse ReLU into the epilogue.

    Returns:
      [B, OUT] f32 activations.
    """
    return _affine_call(x, w, b, apply_relu=apply_relu, block_b=BLOCK_B, block_out=BLOCK_OUT)


def _linear_relu_fwd(x, w, b, apply_relu):
    y = _affine_call(x, w, b, apply_relu=apply_relu, block_b=BLOCK_B, block_out=BLOCK_OUT)
    return y, (x, w, y)


def _linear_relu_bwd(apply_relu, res, g):
    x, w, y = res
    g = g.astype(jnp.float32)
    if apply_relu:
        # y == relu(pre): the mask y > 0 equals pre > 0 almost everywhere.
        g = g * (y > 0.0).astype(jnp.float32)
    dx = matmul(g, w.astype(jnp.float32).T)
    dw = matmul(x.astype(jnp.float32).T, g)
    db = jnp.sum(g, axis=0)
    return dx.astype(x.dtype), dw.astype(w.dtype), db.astype(x.dtype)


linear_relu.defvjp(_linear_relu_fwd, _linear_relu_bwd)


def mlp_forward(params, x):
    """Q-network forward: fused linear+ReLU layers with an affine head.

    Args:
      params: list of (w, b) tuples, layer order.
      x: [B, obs_dim] observations.

    Returns:
      [B, num_actions] Q-values.
    """
    h = x
    for i, (w, b) in enumerate(params):
        last = i == len(params) - 1
        h = linear_relu(h, w, b, not last)
    return h
