"""Layer 1: fused double-DQN TD-target + Huber loss + priority kernel.

A pure VPU (elementwise/reduction) fusion: for each batch row, pick the
online-argmax action, evaluate it under the target network, form the TD
error against the chosen-action Q-value, and emit both the importance-
weighted Huber loss and the |TD| priority that flows back to Reverb's
prioritized table. Blocked over the batch axis so each grid step holds one
(BLOCK_B, A) tile set in VMEM; A (action count) is small for the benchmark
domains, making this memory-bound — fusing the five elementwise stages into
one kernel avoids four HBM round-trips.

Runs with `interpret=True` on this image (see mlp.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_B = 256


def _td_kernel(q_chosen_ref, q_no_ref, q_nt_ref, r_ref, d_ref, w_ref, loss_ref, prio_ref, *, gamma, delta):
    q_no = q_no_ref[...].astype(jnp.float32)  # [BM, A] online Q(s')
    q_nt = q_nt_ref[...].astype(jnp.float32)  # [BM, A] target Q(s')
    r = r_ref[...].astype(jnp.float32)  # [BM]
    d = d_ref[...].astype(jnp.float32)  # [BM]
    w = w_ref[...].astype(jnp.float32)  # [BM]
    q_chosen = q_chosen_ref[...].astype(jnp.float32)  # [BM]

    # Double DQN: online argmax, target evaluation — as a max over a mask so
    # it stays a dense VPU op (no gather).
    best_mask = q_no == jnp.max(q_no, axis=-1, keepdims=True)
    # Break ties toward the first action, like argmax.
    first_best = jnp.cumsum(best_mask.astype(jnp.int32), axis=-1) == 1
    pick = jnp.logical_and(best_mask, first_best)
    q_eval = jnp.sum(jnp.where(pick, q_nt, 0.0), axis=-1)

    target = r + gamma * d * q_eval
    td = q_chosen - target

    abs_err = jnp.abs(td)
    quad = jnp.minimum(abs_err, delta)
    lin = abs_err - quad
    loss_ref[...] = w * (0.5 * quad * quad + delta * lin)
    prio_ref[...] = abs_err


def _td_targets_kernel(q_no_ref, q_nt_ref, r_ref, d_ref, o_ref, *, gamma):
    q_no = q_no_ref[...].astype(jnp.float32)
    q_nt = q_nt_ref[...].astype(jnp.float32)
    best_mask = q_no == jnp.max(q_no, axis=-1, keepdims=True)
    first_best = jnp.cumsum(best_mask.astype(jnp.int32), axis=-1) == 1
    pick = jnp.logical_and(best_mask, first_best)
    q_eval = jnp.sum(jnp.where(pick, q_nt, 0.0), axis=-1)
    o_ref[...] = r_ref[...].astype(jnp.float32) + gamma * d_ref[...].astype(jnp.float32) * q_eval


@functools.partial(jax.jit, static_argnames=("gamma", "block_b"))
def td_targets(q_next_online, q_next_target, rewards, discounts, *, gamma, block_b=BLOCK_B):
    """Fused double-DQN TD targets [B] (no gradient path — consumed under
    `stop_gradient` by the train step)."""
    batch, num_actions = q_next_online.shape
    bm = min(block_b, batch)
    grid = (pl.cdiv(batch, bm),)
    row = pl.BlockSpec((bm,), lambda i: (i,))
    mat = pl.BlockSpec((bm, num_actions), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_td_targets_kernel, gamma=gamma),
        out_shape=jax.ShapeDtypeStruct((batch,), jnp.float32),
        grid=grid,
        in_specs=[mat, mat, row, row],
        out_specs=row,
        interpret=True,
    )(q_next_online, q_next_target, rewards, discounts)


@functools.partial(jax.jit, static_argnames=("gamma", "delta", "block_b"))
def td_loss_and_priorities(
    q_chosen, q_next_online, q_next_target, rewards, discounts, weights, *, gamma, delta=1.0, block_b=BLOCK_B
):
    """Fused per-example weighted Huber TD loss + |TD| priorities.

    Args:
      q_chosen: [B] Q(s, a) for the taken actions.
      q_next_online: [B, A] online net at s'.
      q_next_target: [B, A] target net at s'.
      rewards: [B]; discounts: [B] (0 at terminal); weights: [B] importance
        weights from the prioritized sampler.
      gamma: scalar discount.
      delta: Huber transition point.

    Returns:
      (loss [B], priorities [B]) — both f32.
    """
    batch, _num_actions = q_next_online.shape
    bm = min(block_b, batch)
    grid = (pl.cdiv(batch, bm),)

    row = pl.BlockSpec((bm,), lambda i: (i,))
    mat = pl.BlockSpec((bm, _num_actions), lambda i: (i, 0))

    return pl.pallas_call(
        functools.partial(_td_kernel, gamma=gamma, delta=delta),
        out_shape=(
            jax.ShapeDtypeStruct((batch,), jnp.float32),
            jax.ShapeDtypeStruct((batch,), jnp.float32),
        ),
        grid=grid,
        in_specs=[row, mat, mat, row, row, row],
        out_specs=(row, row),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(q_chosen, q_next_online, q_next_target, rewards, discounts, weights)
