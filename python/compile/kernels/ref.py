"""Pure-jnp reference oracles for the Pallas kernels (Layer 1 correctness).

Every Pallas kernel in this package has an exact (up to float tolerance)
reference implementation here; pytest + hypothesis sweep shapes/dtypes and
assert allclose between the kernel and its oracle.
"""

import jax.numpy as jnp


def linear_relu_ref(x, w, b, *, apply_relu=True):
    """Reference for the fused linear(+bias)(+ReLU) kernel.

    Args:
      x: [B, IN] activations.
      w: [IN, OUT] weights.
      b: [OUT] bias.
      apply_relu: fuse a ReLU after the affine transform.

    Returns:
      [B, OUT] activations, computed in f32.
    """
    y = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32)) + b.astype(jnp.float32)
    if apply_relu:
        y = jnp.maximum(y, 0.0)
    return y


def td_targets_ref(q_next_online, q_next_target, rewards, discounts, *, gamma):
    """Reference for the fused double-DQN TD-target kernel.

    Double DQN: the *online* network picks the argmax action, the *target*
    network evaluates it.

    Args:
      q_next_online: [B, A] online-network Q-values at s'.
      q_next_target: [B, A] target-network Q-values at s'.
      rewards: [B] (possibly n-step accumulated) rewards.
      discounts: [B] per-transition discounts (0 at terminal).
      gamma: scalar discount base applied on top of `discounts`.

    Returns:
      [B] TD targets r + gamma * d * Q_target(s', argmax_a Q_online(s', a)).
    """
    best = jnp.argmax(q_next_online, axis=-1)
    q_eval = jnp.take_along_axis(q_next_target, best[:, None], axis=-1)[:, 0]
    return rewards + gamma * discounts * q_eval


def huber_ref(td_error, *, delta=1.0):
    """Reference Huber loss (elementwise) on TD errors."""
    abs_err = jnp.abs(td_error)
    quad = jnp.minimum(abs_err, delta)
    lin = abs_err - quad
    return 0.5 * quad * quad + delta * lin


def td_loss_and_priorities_ref(
    q_chosen, q_next_online, q_next_target, rewards, discounts, weights, *, gamma, delta=1.0
):
    """Reference for the full fused TD kernel output.

    Returns (per-example weighted Huber loss, |TD error| priorities).
    """
    targets = td_targets_ref(q_next_online, q_next_target, rewards, discounts, gamma=gamma)
    td_error = q_chosen - targets
    loss = weights * huber_ref(td_error, delta=delta)
    return loss, jnp.abs(td_error)
