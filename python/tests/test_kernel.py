"""Layer-1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes and dtypes; every comparison is
`np.testing.assert_allclose` against `kernels.ref`.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import mlp, ref, td

# Keep hypothesis deadline generous: interpret-mode Pallas is slow.
COMMON = dict(deadline=None, max_examples=25)


def rand(rng, shape, dtype=np.float32, scale=1.0):
    return jnp.asarray(rng.normal(size=shape) * scale, dtype)


# ---------------------------------------------------------------------------
# linear_relu
# ---------------------------------------------------------------------------


@settings(**COMMON)
@given(
    batch=st.integers(1, 300),
    d_in=st.integers(1, 130),
    d_out=st.integers(1, 200),
    relu=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_linear_relu_matches_ref(batch, d_in, d_out, relu, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, (batch, d_in))
    w = rand(rng, (d_in, d_out))
    b = rand(rng, (d_out,))
    got = mlp.linear_relu(x, w, b, relu)
    want = ref.linear_relu_ref(x, w, b, apply_relu=relu)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(**COMMON)
@given(
    batch=st.integers(1, 64),
    d_in=st.integers(1, 48),
    d_out=st.integers(1, 48),
    seed=st.integers(0, 2**31 - 1),
)
def test_linear_relu_gradients_match_ref(batch, d_in, d_out, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, (batch, d_in))
    w = rand(rng, (d_in, d_out))
    b = rand(rng, (d_out,))

    def k_loss(x, w, b):
        return jnp.sum(mlp.linear_relu(x, w, b, True) ** 2)

    def r_loss(x, w, b):
        return jnp.sum(ref.linear_relu_ref(x, w, b) ** 2)

    gk = jax.grad(k_loss, argnums=(0, 1, 2))(x, w, b)
    gr = jax.grad(r_loss, argnums=(0, 1, 2))(x, w, b)
    for a, e in zip(gk, gr):
        np.testing.assert_allclose(a, e, rtol=1e-4, atol=1e-4)


def test_linear_relu_bf16_inputs():
    rng = np.random.default_rng(0)
    x = rand(rng, (8, 16)).astype(jnp.bfloat16)
    w = rand(rng, (16, 8)).astype(jnp.bfloat16)
    b = rand(rng, (8,)).astype(jnp.bfloat16)
    got = mlp.linear_relu(x, w, b, True)
    want = ref.linear_relu_ref(x, w, b)
    assert got.dtype == jnp.float32
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("batch,d_out", [(1, 1), (128, 128), (129, 127), (257, 3)])
def test_linear_relu_tile_boundaries(batch, d_out):
    """Shapes exactly on / straddling the (128, 128) tile grid."""
    rng = np.random.default_rng(1)
    x = rand(rng, (batch, 7))
    w = rand(rng, (7, d_out))
    b = rand(rng, (d_out,))
    np.testing.assert_allclose(
        mlp.linear_relu(x, w, b, True),
        ref.linear_relu_ref(x, w, b),
        rtol=1e-5,
        atol=1e-5,
    )


def test_matmul_matches_jnp():
    rng = np.random.default_rng(2)
    a = rand(rng, (100, 30))
    b = rand(rng, (30, 50))
    np.testing.assert_allclose(mlp.matmul(a, b), a @ b, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# TD kernels
# ---------------------------------------------------------------------------


@settings(**COMMON)
@given(
    batch=st.integers(1, 600),
    actions=st.integers(2, 18),
    gamma=st.floats(0.5, 0.999),
    seed=st.integers(0, 2**31 - 1),
)
def test_td_targets_match_ref(batch, actions, gamma, seed):
    rng = np.random.default_rng(seed)
    q_no = rand(rng, (batch, actions))
    q_nt = rand(rng, (batch, actions))
    r = rand(rng, (batch,))
    d = jnp.asarray(rng.integers(0, 2, size=(batch,)), jnp.float32)
    got = td.td_targets(q_no, q_nt, r, d, gamma=gamma)
    want = ref.td_targets_ref(q_no, q_nt, r, d, gamma=gamma)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(**COMMON)
@given(
    batch=st.integers(1, 400),
    actions=st.integers(2, 10),
    delta=st.floats(0.25, 4.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_td_loss_and_priorities_match_ref(batch, actions, delta, seed):
    rng = np.random.default_rng(seed)
    qc = rand(rng, (batch,), scale=2.0)
    q_no = rand(rng, (batch, actions))
    q_nt = rand(rng, (batch, actions))
    r = rand(rng, (batch,))
    d = jnp.asarray(rng.integers(0, 2, size=(batch,)), jnp.float32)
    w = jnp.asarray(rng.uniform(0.1, 1.0, size=(batch,)), jnp.float32)
    got_l, got_p = td.td_loss_and_priorities(qc, q_no, q_nt, r, d, w, gamma=0.99, delta=delta)
    want_l, want_p = ref.td_loss_and_priorities_ref(
        qc, q_no, q_nt, r, d, w, gamma=0.99, delta=delta
    )
    np.testing.assert_allclose(got_l, want_l, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(got_p, want_p, rtol=1e-5, atol=1e-5)


def test_td_targets_tie_breaking_matches_argmax():
    """Duplicate maxima must resolve like argmax (first index wins)."""
    q_no = jnp.asarray([[1.0, 1.0, 0.5], [0.2, 0.9, 0.9]], jnp.float32)
    q_nt = jnp.asarray([[10.0, 20.0, 30.0], [1.0, 2.0, 3.0]], jnp.float32)
    r = jnp.zeros((2,), jnp.float32)
    d = jnp.ones((2,), jnp.float32)
    got = td.td_targets(q_no, q_nt, r, d, gamma=1.0)
    np.testing.assert_allclose(got, [10.0, 2.0])


def test_terminal_transitions_ignore_bootstrap():
    q_no = jnp.asarray([[5.0, 1.0]], jnp.float32)
    q_nt = jnp.asarray([[100.0, 100.0]], jnp.float32)
    r = jnp.asarray([2.0], jnp.float32)
    d = jnp.zeros((1,), jnp.float32)  # terminal
    got = td.td_targets(q_no, q_nt, r, d, gamma=0.99)
    np.testing.assert_allclose(got, [2.0])


def test_huber_regions():
    e = jnp.asarray([-3.0, -1.0, -0.25, 0.0, 0.25, 1.0, 3.0], jnp.float32)
    got = ref.huber_ref(e, delta=1.0)
    want = np.where(np.abs(e) <= 1.0, 0.5 * np.square(e), np.abs(e) - 0.5)
    np.testing.assert_allclose(got, want, rtol=1e-6)
