"""Layer-2 correctness: the AOT'd learner graph vs a Pallas-free reference,
plus shape/manifest checks for the artifacts the Rust runtime consumes.
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model

OBS, ACT, HID = 4, 2, [16, 16]


def make_state(seed=0):
    params = model.init_params(jax.random.PRNGKey(seed), OBS, HID, ACT)
    target = [p + 0.01 for p in params]
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    return params, target, m, v


def make_batch(rng, batch):
    return dict(
        obs=jnp.asarray(rng.normal(size=(batch, OBS)), jnp.float32),
        actions=jnp.asarray(rng.integers(0, ACT, size=(batch,)), jnp.int32),
        rewards=jnp.asarray(rng.normal(size=(batch,)), jnp.float32),
        discounts=jnp.asarray(rng.integers(0, 2, size=(batch,)), jnp.float32),
        next_obs=jnp.asarray(rng.normal(size=(batch, OBS)), jnp.float32),
        weights=jnp.asarray(rng.uniform(0.2, 1.0, size=(batch,)), jnp.float32),
    )


def test_q_values_match_ref():
    rng = np.random.default_rng(0)
    params, *_ = make_state()
    obs = jnp.asarray(rng.normal(size=(32, OBS)), jnp.float32)
    np.testing.assert_allclose(
        model.q_values(params, obs), model.q_values_ref(params, obs), rtol=1e-5, atol=1e-5
    )


@settings(deadline=None, max_examples=10)
@given(batch=st.integers(1, 96), seed=st.integers(0, 10_000))
def test_train_step_matches_reference(batch, seed):
    rng = np.random.default_rng(seed)
    params, target, m, v = make_state(seed % 7)
    b = make_batch(rng, batch)
    step = jnp.asarray(0.0, jnp.float32)

    kw = dict(gamma=0.99, lr=1e-3)
    got = model._train_step_impl(
        params, target, m, v, step, b["obs"], b["actions"], b["rewards"],
        b["discounts"], b["next_obs"], b["weights"], beta1=0.9, beta2=0.999,
        eps=1e-8, huber_delta=1.0, **kw,
    )
    want = model.train_step_ref(
        params, target, m, v, step, b["obs"], b["actions"], b["rewards"],
        b["discounts"], b["next_obs"], b["weights"], **kw,
    )
    # params, m, v
    for got_list, want_list in zip(got[:3], want[:3]):
        for a, e in zip(got_list, want_list):
            np.testing.assert_allclose(a, e, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(got[3], want[3])  # step
    np.testing.assert_allclose(got[4], want[4], rtol=1e-4, atol=1e-6)  # loss
    np.testing.assert_allclose(got[5], want[5], rtol=1e-4, atol=1e-5)  # priorities


def test_train_step_decreases_loss_on_fixed_batch():
    rng = np.random.default_rng(3)
    params, target, m, v = make_state(1)
    b = make_batch(rng, 64)
    step = jnp.asarray(0.0, jnp.float32)
    losses = []
    for _ in range(60):
        params, m, v, step, loss, _ = model._train_step_impl(
            params, target, m, v, step, b["obs"], b["actions"], b["rewards"],
            b["discounts"], b["next_obs"], b["weights"], gamma=0.99, lr=3e-3,
            beta1=0.9, beta2=0.999, eps=1e-8, huber_delta=1.0,
        )
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.5, f"{losses[0]} -> {losses[-1]}"


def test_flat_signature_arity():
    num_layers = len(HID) + 1
    P = 2 * num_layers
    ts = model.make_train_step(num_layers)
    params, target, m, v = make_state()
    rng = np.random.default_rng(0)
    b = make_batch(rng, 8)
    out = ts(
        *params, *target, *m, *v, jnp.asarray(0.0, jnp.float32),
        b["obs"], b["actions"], b["rewards"], b["discounts"], b["next_obs"], b["weights"],
    )
    assert len(out) == 3 * P + 3
    assert out[3 * P].shape == ()  # step
    assert out[3 * P + 1].shape == ()  # loss
    assert out[3 * P + 2].shape == (8,)  # priorities


def test_priorities_are_abs_td_errors():
    rng = np.random.default_rng(5)
    params, target, m, v = make_state(2)
    b = make_batch(rng, 16)
    *_, priorities = model._train_step_impl(
        params, target, m, v, jnp.asarray(0.0), b["obs"], b["actions"], b["rewards"],
        b["discounts"], b["next_obs"], b["weights"], gamma=0.99, lr=1e-3,
        beta1=0.9, beta2=0.999, eps=1e-8, huber_delta=1.0,
    )
    assert (np.asarray(priorities) >= 0).all()
    assert priorities.shape == (16,)


def test_aot_meta_manifest(tmp_path):
    from compile import aot

    aot.write_meta(
        tmp_path / "meta.txt", obs_dim=4, hidden=[64, 64], num_actions=2,
        batch=64, infer_batch=1, gamma=0.99, lr=1e-3,
    )
    text = (tmp_path / "meta.txt").read_text()
    lines = dict(l.split(" ", 1) for l in text.strip().splitlines())
    assert lines["obs_dim"] == "4"
    assert lines["hidden"] == "64 64"
    assert lines["layer0"] == "4 64"
    assert lines["layer2"] == "64 2"


def test_hlo_text_lowering_smoke():
    """The full AOT path produces parseable-looking HLO text."""
    from compile import aot

    infer_lowered, train_lowered = aot.lower_all(
        obs_dim=3, hidden=[8], num_actions=2, batch=4, infer_batch=1, gamma=0.99, lr=1e-3
    )
    infer_text = aot.to_hlo_text(infer_lowered)
    train_text = aot.to_hlo_text(train_lowered)
    assert "HloModule" in infer_text
    assert "HloModule" in train_text
    # infer: 2*(num_layers=2) params + obs = 5 inputs
    assert "parameter(4)" in infer_text
    assert "parameter(5)" not in infer_text
    # train: 4*4 + 7 = 23 inputs
    assert "parameter(22)" in train_text
    assert "parameter(23)" not in train_text
