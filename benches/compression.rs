//! §5-preamble reproduction: chunk compression on Atari-like correlated
//! frames vs random data, plus a codec × storage-tier sweep.
//!
//! Paper claim: "in Atari we observe compression rates of up to 90% in
//! sequences of 40 frames. The effective throughput would therefore be up
//! to 10x higher in that scenario." We sweep chunk length (1/10/40
//! frames), data source (correlated vs random), and codec (zstd vs
//! delta+zstd), reporting compression ratio, effective-throughput
//! multiplier, and encode/decode speed.
//!
//! The tier sweep then resolves the same chunks out of a hot (in-memory)
//! and a cold (CRC-framed spill file) ChunkStore: a better codec shrinks
//! the cold record, so the codec choice compounds with tiering — the
//! motivation for per-column codec rules in `TrajectoryWriterOptions`.
//!
//! Run: `cargo bench --bench compression`
//! (REVERB_BENCH_FAST=1 for the CI quick pass; emits BENCH_compression.json.)

use reverb::core::chunk::{Chunk, Compression};
use reverb::core::chunk_store::{ChunkStore, TieringConfig};
use reverb::core::tensor::Tensor;
use reverb::rl::env::AtariSim;
use reverb::util::bench::{fast_mode, print_row};
use reverb::util::stats::{json_f64_prec, Samples};
use std::time::{Duration, Instant};

fn frames(sim: &mut AtariSim, n: usize, random: bool) -> Vec<Vec<Tensor>> {
    (0..n)
        .map(|_| {
            let f = if random {
                sim.random_frame()
            } else {
                sim.next_frame().to_vec()
            };
            vec![Tensor::from_u8(&[84, 84], &f).unwrap()]
        })
        .collect()
}

struct CodecRow {
    source: &'static str,
    chunk_len: usize,
    codec: &'static str,
    ratio: f64,
    mult: f64,
    enc_mbps: f64,
    dec_mbps: f64,
}

struct TierRow {
    codec: &'static str,
    tier: &'static str,
    resolve_p50_us: f64,
    resolve_p99_us: f64,
    cold_bytes: u64,
}

/// Resolve every handle `rounds` times, re-demoting between passes when
/// `store` is tiered, and return per-resolve latencies.
fn resolve_latencies(
    store: &ChunkStore,
    handles: &[reverb::core::chunk_store::ChunkHandle],
    rounds: usize,
    cold: bool,
) -> Samples {
    let mut lat = Samples::new();
    for _ in 0..rounds {
        if cold {
            store.run_maintenance();
        }
        for h in handles {
            let t0 = Instant::now();
            let chunk = h.resolve().unwrap();
            lat.add(t0.elapsed().as_secs_f64() * 1e6);
            std::hint::black_box(chunk);
        }
    }
    lat
}

fn main() {
    let fast = fast_mode();
    println!("# Compression: correlated (Atari-like) vs random frames");
    println!("| source | chunk_len | codec | ratio | eff. BPS multiplier | enc MB/s | dec MB/s |");
    println!("|---|---|---|---|---|---|---|");
    let mut sim = AtariSim::new(7, 4);
    let mut codec_rows: Vec<CodecRow> = Vec::new();
    for &random in &[false, true] {
        for &chunk_len in &[1usize, 10, 40] {
            for (codec, name) in [
                (Compression::Zstd { level: 1 }, "zstd1"),
                (Compression::DeltaZstd { level: 1 }, "delta+zstd1"),
            ] {
                let steps = frames(&mut sim, chunk_len, random);
                // Encode/decode timing over enough reps to measure.
                let reps = match (fast, chunk_len) {
                    (true, 1) => 50,
                    (true, _) => 5,
                    (false, 1) => 200,
                    (false, _) => 20,
                };
                let t0 = Instant::now();
                let mut chunk = None;
                for i in 0..reps {
                    chunk = Some(Chunk::from_steps(i as u64, 0, &steps, codec).unwrap());
                }
                let enc = t0.elapsed();
                let chunk = chunk.unwrap();
                let t1 = Instant::now();
                for _ in 0..reps {
                    chunk.to_steps().unwrap();
                }
                let dec = t1.elapsed();

                let raw = chunk.uncompressed_len() as f64;
                let ratio = chunk.compression_ratio();
                let mult = raw / chunk.encoded_len() as f64;
                let mb = raw * reps as f64 / 1e6;
                let row = CodecRow {
                    source: if random { "random" } else { "atari-sim" },
                    chunk_len,
                    codec: name,
                    ratio,
                    mult,
                    enc_mbps: mb / enc.as_secs_f64(),
                    dec_mbps: mb / dec.as_secs_f64(),
                };
                println!(
                    "| {} | {chunk_len} | {name} | {:.1}% | {:.1}x | {:.0} | {:.0} |",
                    row.source,
                    ratio * 100.0,
                    mult,
                    row.enc_mbps,
                    row.dec_mbps,
                );
                codec_rows.push(row);
            }
        }
    }

    // Codec × tier: resolve 40-frame correlated chunks from the hot tier
    // (Arc clone) and from the cold tier (positional read / mmap + CRC +
    // decode). A stronger codec shrinks the cold record it re-reads.
    let n_chunks = if fast { 8 } else { 32 };
    let rounds = if fast { 3 } else { 10 };
    println!("\n# Codec x tier: ChunkStore resolve latency, 40-frame atari chunks");
    println!("| codec | tier | resolve p50 (us) | resolve p99 (us) | cold bytes |");
    println!("|---|---|---|---|---|");
    let dir = std::env::temp_dir().join(format!("rvb_bench_comp_{}", std::process::id()));
    let mut tier_rows: Vec<TierRow> = Vec::new();
    for (codec, name) in [
        (Compression::None, "none"),
        (Compression::Zstd { level: 1 }, "zstd1"),
        (Compression::DeltaZstd { level: 1 }, "delta+zstd1"),
    ] {
        let chunks: Vec<Chunk> = (0..n_chunks)
            .map(|i| {
                let steps = frames(&mut sim, 40, false);
                Chunk::from_steps(i as u64, 0, &steps, codec).unwrap()
            })
            .collect();
        for cold in [false, true] {
            let tier = if cold { "cold" } else { "hot" };
            let store = if cold {
                let d = dir.join(name);
                std::fs::create_dir_all(&d).unwrap();
                let mut cfg = TieringConfig::new(1, &d);
                // Manual maintenance only: keep the background thread out
                // of the measurement.
                cfg.sweep_interval = Duration::from_secs(3600);
                ChunkStore::with_tiering(1, cfg).unwrap()
            } else {
                ChunkStore::with_shards(1)
            };
            let handles: Vec<_> = chunks.iter().map(|c| store.insert(c.clone())).collect();
            let mut lat = resolve_latencies(&store, &handles, rounds, cold);
            let stats = store.stats();
            let row = TierRow {
                codec: name,
                tier,
                resolve_p50_us: lat.percentile(50.0),
                resolve_p99_us: lat.percentile(99.0),
                cold_bytes: stats.cold_bytes,
            };
            print_row(&[
                name.to_string(),
                tier.to_string(),
                format!("{:.1}", row.resolve_p50_us),
                format!("{:.1}", row.resolve_p99_us),
                row.cold_bytes.to_string(),
            ]);
            tier_rows.push(row);
        }
    }
    std::fs::remove_dir_all(&dir).ok();

    let codec_json: Vec<String> = codec_rows
        .iter()
        .map(|r| {
            format!(
                "    {{\"source\": \"{}\", \"chunk_len\": {}, \"codec\": \"{}\", \
                 \"ratio\": {}, \"multiplier\": {}, \"enc_mbps\": {}, \"dec_mbps\": {}}}",
                r.source,
                r.chunk_len,
                r.codec,
                json_f64_prec(r.ratio, 4),
                json_f64_prec(r.mult, 2),
                json_f64_prec(r.enc_mbps, 1),
                json_f64_prec(r.dec_mbps, 1)
            )
        })
        .collect();
    let tier_json: Vec<String> = tier_rows
        .iter()
        .map(|r| {
            format!(
                "    {{\"codec\": \"{}\", \"tier\": \"{}\", \"resolve_p50_us\": {}, \
                 \"resolve_p99_us\": {}, \"cold_bytes\": {}}}",
                r.codec,
                r.tier,
                json_f64_prec(r.resolve_p50_us, 2),
                json_f64_prec(r.resolve_p99_us, 2),
                r.cold_bytes
            )
        })
        .collect();
    let json = format!(
        "{{\n  \"bench\": \"compression\",\n  \"fast\": {fast},\n  \
         \"codecs\": [\n{}\n  ],\n  \"tiers\": [\n{}\n  ]\n}}\n",
        codec_json.join(",\n"),
        tier_json.join(",\n")
    );
    std::fs::write("BENCH_compression.json", &json).expect("write BENCH_compression.json");
    println!("\nwrote BENCH_compression.json");

    println!("\npaper: up to 90% on 40-frame sequences -> ~10x effective throughput;");
    println!("random data sees ~0% (the figure-5/6 benchmarks use random data on purpose).");
}
