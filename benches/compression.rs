//! §5-preamble reproduction: chunk compression on Atari-like correlated
//! frames vs random data.
//!
//! Paper claim: "in Atari we observe compression rates of up to 90% in
//! sequences of 40 frames. The effective throughput would therefore be up
//! to 10x higher in that scenario." We sweep chunk length (1/10/40
//! frames), data source (correlated vs random), and codec (zstd vs
//! delta+zstd), reporting compression ratio, effective-throughput
//! multiplier, and encode/decode speed.
//!
//! Run: `cargo bench --bench compression`

use reverb::core::chunk::{Chunk, Compression};
use reverb::core::tensor::Tensor;
use reverb::rl::env::AtariSim;
use std::time::Instant;

fn frames(sim: &mut AtariSim, n: usize, random: bool) -> Vec<Vec<Tensor>> {
    (0..n)
        .map(|_| {
            let f = if random {
                sim.random_frame()
            } else {
                sim.next_frame().to_vec()
            };
            vec![Tensor::from_u8(&[84, 84], &f).unwrap()]
        })
        .collect()
}

fn main() {
    println!("# Compression: correlated (Atari-like) vs random frames");
    println!("| source | chunk_len | codec | ratio | eff. BPS multiplier | enc MB/s | dec MB/s |");
    println!("|---|---|---|---|---|---|---|");
    let mut sim = AtariSim::new(7, 4);
    for &random in &[false, true] {
        for &chunk_len in &[1usize, 10, 40] {
            for (codec, name) in [
                (Compression::Zstd { level: 1 }, "zstd1"),
                (Compression::DeltaZstd { level: 1 }, "delta+zstd1"),
            ] {
                let steps = frames(&mut sim, chunk_len, random);
                // Encode/decode timing over enough reps to measure.
                let reps = if chunk_len == 1 { 200 } else { 20 };
                let t0 = Instant::now();
                let mut chunk = None;
                for i in 0..reps {
                    chunk = Some(Chunk::from_steps(i as u64, 0, &steps, codec).unwrap());
                }
                let enc = t0.elapsed();
                let chunk = chunk.unwrap();
                let t1 = Instant::now();
                for _ in 0..reps {
                    chunk.to_steps().unwrap();
                }
                let dec = t1.elapsed();

                let raw = chunk.uncompressed_len() as f64;
                let ratio = chunk.compression_ratio();
                let mult = raw / chunk.encoded_len() as f64;
                let mb = raw * reps as f64 / 1e6;
                println!(
                    "| {} | {chunk_len} | {name} | {:.1}% | {:.1}x | {:.0} | {:.0} |",
                    if random { "random" } else { "atari-sim" },
                    ratio * 100.0,
                    mult,
                    mb / enc.as_secs_f64(),
                    mb / dec.as_secs_f64(),
                );
            }
        }
    }
    println!("\npaper: up to 90% on 40-frame sequences -> ~10x effective throughput;");
    println!("random data sees ~0% (the figure-5/6 benchmarks use random data on purpose).");
}
