//! Concurrency sweep (DESIGN.md §11): mixed insert+sample QPS under
//! {8, 64, 256, 1024} concurrent clients × {threaded, event} service
//! models, with the server pinned to 4 service threads.
//!
//! The paper's headline serving claim is "thousands of concurrent
//! clients" (§1, Figs. 5/6); the thread-per-connection seed made
//! connection count the ceiling long before table throughput. The
//! event-driven core decouples them: expected result is event-model QPS
//! >= threaded-model QPS from 256 clients up, while holding >= 1024
//! concurrent live connections on 4 workers (each client keeps a writer
//! and a sampler connection open for the whole window).
//!
//! Run: `cargo bench --bench concurrency`
//! (REVERB_BENCH_FAST=1 for a quick CI pass — fewer tiers, shorter
//! windows.) Emits `BENCH_concurrency.json` for the CI perf trajectory.

use reverb::core::table::TableConfig;
use reverb::net::poller::ensure_fd_capacity;
use reverb::net::server::Server;
use reverb::util::bench::*;
use reverb::util::stats::{fmt_qps, json_f64_prec};
use reverb::ServiceModel;
use std::time::Duration;

const SERVICE_THREADS: usize = 4;
const PAYLOAD_FLOATS: usize = 100; // 400 B, the paper's small-payload point

fn model_name(model: ServiceModel) -> &'static str {
    match model {
        ServiceModel::Threaded => "threaded",
        ServiceModel::Event => "event",
    }
}

/// One (model, client-count) measurement on a fresh server.
fn mixed_qps(model: ServiceModel, clients: usize, window: Duration) -> Throughput {
    let server = Server::builder()
        .table(TableConfig::uniform_replay("t", 500_000))
        .service_model(model)
        .service_threads(SERVICE_THREADS)
        .bind("127.0.0.1:0")
        .unwrap();
    let addr = format!("tcp://{}", server.local_addr());
    // Pre-fill so samplers never wait on min_size.
    prefill_table(&server.table("t").unwrap(), 1_000, PAYLOAD_FLOATS);
    let t = run_mixed_clients(&addr, "t", clients, PAYLOAD_FLOATS, window);
    drop(server);
    t
}

fn main() {
    let fast = fast_mode();
    let tiers: &[usize] = if fast { &[8, 64] } else { &[8, 64, 256, 1024] };
    let window = if fast {
        Duration::from_millis(400)
    } else {
        Duration::from_millis(2_000)
    };
    // Each client holds ~3 descriptors on each side (writer conn, sampler
    // conn, transients), plus the server's accept/poller overhead — all in
    // one process.
    ensure_fd_capacity(16_384);

    println!(
        "# Concurrency sweep: {SERVICE_THREADS} service threads, mixed insert+sample, 400B payloads"
    );
    println!("| clients | threaded QPS | event QPS | event/threaded |");
    println!("|---|---|---|---|");

    let mut threaded_qps = Vec::new();
    let mut event_qps = Vec::new();
    let mut high_tier_holds = true;
    for &clients in tiers {
        let threaded = mixed_qps(ServiceModel::Threaded, clients, window);
        let event = mixed_qps(ServiceModel::Event, clients, window);
        let ratio = event.qps() / threaded.qps().max(1.0);
        if clients >= 256 && event.qps() < threaded.qps() {
            high_tier_holds = false;
        }
        threaded_qps.push(threaded.qps());
        event_qps.push(event.qps());
        print_row(&[
            clients.to_string(),
            fmt_qps(threaded.qps()),
            fmt_qps(event.qps()),
            format!("{ratio:.2}x"),
        ]);
    }

    // Machine-readable trajectory for CI (BENCH_concurrency.json).
    let fmt_list = |xs: &[f64]| {
        xs.iter()
            .map(|&q| json_f64_prec(q, 1))
            .collect::<Vec<_>>()
            .join(",")
    };
    let json = format!(
        "{{\"bench\":\"concurrency\",\"service_threads\":{SERVICE_THREADS},\
         \"payload_floats\":{PAYLOAD_FLOATS},\"fast\":{fast},\
         \"clients\":[{}],\"threaded_qps\":[{}],\"event_qps\":[{}],\
         \"models\":[\"{}\",\"{}\"]}}",
        tiers
            .iter()
            .map(|c| c.to_string())
            .collect::<Vec<_>>()
            .join(","),
        fmt_list(&threaded_qps),
        fmt_list(&event_qps),
        model_name(ServiceModel::Threaded),
        model_name(ServiceModel::Event),
    );
    std::fs::write("BENCH_concurrency.json", &json).expect("write BENCH_concurrency.json");
    println!("\nwrote BENCH_concurrency.json");

    println!();
    if fast {
        println!("RESULT: SMOKE — fast mode exercises both models at low tiers only.");
    } else if high_tier_holds {
        println!(
            "RESULT: PASS — event-model QPS >= threaded-model QPS at every tier >= 256 clients."
        );
    } else {
        println!(
            "RESULT: WARNING — threaded beat event at a >=256-client tier; rerun on an idle machine."
        );
    }
}
