//! Pipelined client + batched wire ops (DESIGN.md §13): single-connection
//! insert throughput over depth ∈ {1, 4, 16, 64} in-flight requests ×
//! batch ∈ {1, 16, 128} items per frame, against a sharded table — plus
//! batched vs per-op priority updates.
//!
//! The blocking client is the (depth=1, batch=1) cell: one request on the
//! wire, one ack round-trip per item. PR 5 gave the server event-driven
//! capacity; this measures how much of it one connection can now use.
//! Expected result: depth >= 16 sustains >= 2x the blocking cell, and
//! batched priority updates run >= 4x the per-op path.
//!
//! Run: `cargo bench --bench pipeline`
//! (REVERB_BENCH_FAST=1 for a quick CI pass — fewer cells, shorter
//! windows.) Emits `BENCH_pipeline.json` for the CI perf trajectory.

use reverb::core::table::TableConfig;
use reverb::net::wire::{Message, PriorityUpdateOp, WireItem};
use reverb::util::bench::*;
use reverb::util::rng::Pcg32;
use reverb::util::stats::{fmt_qps, json_f64_prec};
use reverb::{Chunk, Compression, Pipeline, Server};
use std::collections::VecDeque;
use std::sync::Arc;
use std::time::{Duration, Instant};

const PAYLOAD_FLOATS: usize = 100; // 400 B, the paper's small-payload point
const SHARDS: usize = 4;

/// One random single-step chunk + the wire item referencing it.
fn mk_op(key: u64, rng: &mut Pcg32) -> (Arc<Chunk>, WireItem) {
    let steps = vec![random_step(PAYLOAD_FLOATS, rng)];
    let chunk = Arc::new(Chunk::from_steps(key, 0, &steps, Compression::None).unwrap());
    let item = WireItem {
        key: key | (1 << 62), // item keys distinct from chunk keys
        table: "t".into(),
        priority: 1.0,
        chunk_keys: vec![key],
        offset: 0,
        length: 1,
        times_sampled: 0,
        columns: None,
    };
    (chunk, item)
}

/// Single-connection insert QPS at one (depth, batch) cell: chunks + items
/// travel `batch` per frame, up to `depth` unacked frames ride the wire.
fn insert_qps(addr: &str, depth: usize, batch: usize, window: Duration) -> f64 {
    let pipe = Pipeline::connect(addr, depth).unwrap();
    let mut rng = Pcg32::new(0x9e37_79b9, ((depth as u64) << 8) | batch as u64);
    let mut next_key = 1u64;
    let mut outstanding: VecDeque<(reverb::Completion, usize)> = VecDeque::new();
    let mut acked = 0u64;
    let start = Instant::now();
    while start.elapsed() < window {
        let mut chunks = Vec::with_capacity(batch);
        let mut items = Vec::with_capacity(batch);
        for _ in 0..batch {
            let (c, i) = mk_op(next_key, &mut rng);
            next_key += 1;
            chunks.push(c);
            items.push(i);
        }
        pipe.send_unacked(Message::InsertChunks { chunks }).unwrap();
        let completion = if batch == 1 {
            // The v1 blocking-client frame, for a faithful baseline cell.
            let item = items.pop().expect("batch of 1");
            pipe.submit(|id| Message::CreateItem {
                id,
                item,
                timeout_ms: 30_000,
            })
            .unwrap()
        } else {
            pipe.submit(|id| Message::CreateItemBatch {
                id,
                items,
                timeout_ms: 30_000,
                trace: None,
            })
            .unwrap()
        };
        pipe.flush().unwrap();
        outstanding.push_back((completion, batch));
        while outstanding.len() >= depth {
            let (c, n) = outstanding.pop_front().expect("non-empty");
            match c.wait().unwrap() {
                Message::Ack { .. } => acked += n as u64,
                Message::BatchReply { results, .. } => {
                    acked += results.len() as u64;
                }
                other => panic!("unexpected reply {other:?}"),
            }
        }
    }
    while let Some((c, n)) = outstanding.pop_front() {
        c.wait().unwrap();
        acked += n as u64;
    }
    acked as f64 / start.elapsed().as_secs_f64()
}

/// Priority-update ops/sec with `batch` single-update ops per frame
/// (batch = 1 uses the v1 per-op `MutatePriorities` frame), blocking on
/// each frame's reply (depth 1) so the measurement isolates batching.
fn mutate_qps(addr: &str, keys: &[u64], batch: usize, window: Duration) -> f64 {
    let pipe = Pipeline::connect(addr, 1).unwrap();
    let mut updated = 0u64;
    let mut i = 0usize;
    let start = Instant::now();
    while start.elapsed() < window {
        if batch == 1 {
            let key = keys[i % keys.len()];
            i += 1;
            pipe.submit(|id| Message::MutatePriorities {
                id,
                table: "t".into(),
                updates: vec![(key, 2.0)],
                deletes: vec![],
            })
            .unwrap()
            .expect_ack()
            .unwrap();
            updated += 1;
        } else {
            let ops: Vec<PriorityUpdateOp> = (0..batch)
                .map(|_| {
                    let key = keys[i % keys.len()];
                    i += 1;
                    PriorityUpdateOp {
                        table: "t".into(),
                        updates: vec![(key, 2.0)],
                        deletes: vec![],
                    }
                })
                .collect();
            let results = pipe
                .submit(|id| Message::PriorityUpdateBatch { id, ops, trace: None })
                .unwrap()
                .expect_batch()
                .unwrap();
            updated += results.len() as u64;
        }
    }
    updated as f64 / start.elapsed().as_secs_f64()
}

fn main() {
    let fast = fast_mode();
    let depths: &[usize] = if fast { &[1, 16] } else { &[1, 4, 16, 64] };
    let batches: &[usize] = if fast { &[1, 16] } else { &[1, 16, 128] };
    let window = if fast {
        Duration::from_millis(300)
    } else {
        Duration::from_millis(1_500)
    };

    let server = Server::builder()
        .table(TableConfig::uniform_replay("t", 4_000_000).with_shards(SHARDS))
        .bind("127.0.0.1:0")
        .unwrap();
    let addr = format!("tcp://{}", server.local_addr());

    println!(
        "# Pipeline sweep: one connection, {SHARDS}-shard table, 400B items, \
         depth x batch insert QPS"
    );
    let mut header = vec!["depth \\ batch".to_string()];
    header.extend(batches.iter().map(|b| b.to_string()));
    print_row(&header);
    print_row(&vec!["---".to_string(); batches.len() + 1]);

    let mut insert_grid: Vec<Vec<f64>> = Vec::new();
    for &depth in depths {
        let mut row_qps = Vec::new();
        let mut row = vec![depth.to_string()];
        for &batch in batches {
            let qps = insert_qps(&addr, depth, batch, window);
            row.push(fmt_qps(qps));
            row_qps.push(qps);
        }
        print_row(&row);
        insert_grid.push(row_qps);
    }
    let blocking = insert_grid[0][0];
    let best_deep = depths
        .iter()
        .zip(&insert_grid)
        .filter(|(d, _)| **d >= 16)
        .flat_map(|(_, row)| row.iter().copied())
        .fold(0.0f64, f64::max);
    let insert_speedup = best_deep / blocking.max(1.0);

    // Priority mutations: per-op vs batched frames on a prefilled table.
    prefill_table(&server.table("t").unwrap(), 1_024, PAYLOAD_FLOATS);
    let keys: Vec<u64> = {
        let (items, _, _) = server.table("t").unwrap().snapshot();
        items.iter().map(|i| i.key).collect()
    };
    println!("\n# Priority updates: ops/sec per frame shape (depth 1)");
    print_row(&["batch".into(), "updates/s".into(), "vs per-op".into()]);
    print_row(&["---".into(), "---".into(), "---".into()]);
    let mut mutate_qps_list = Vec::new();
    for &batch in batches {
        let qps = mutate_qps(&addr, &keys, batch, window);
        let base = *mutate_qps_list.first().unwrap_or(&qps);
        print_row(&[
            batch.to_string(),
            fmt_qps(qps),
            format!("{:.2}x", qps / base.max(1.0)),
        ]);
        mutate_qps_list.push(qps);
    }
    let mutate_speedup = mutate_qps_list.last().unwrap() / mutate_qps_list[0].max(1.0);

    let fmt_list = |xs: &[f64]| {
        xs.iter()
            .map(|&q| json_f64_prec(q, 1))
            .collect::<Vec<_>>()
            .join(",")
    };
    let json = format!(
        "{{\"bench\":\"pipeline\",\"shards\":{SHARDS},\
         \"payload_floats\":{PAYLOAD_FLOATS},\"fast\":{fast},\
         \"depths\":[{}],\"batches\":[{}],\"insert_qps\":[{}],\
         \"blocking_qps\":{},\"insert_speedup\":{},\
         \"mutate_qps\":[{}],\"mutate_speedup\":{}}}",
        depths
            .iter()
            .map(|d| d.to_string())
            .collect::<Vec<_>>()
            .join(","),
        batches
            .iter()
            .map(|b| b.to_string())
            .collect::<Vec<_>>()
            .join(","),
        insert_grid
            .iter()
            .map(|row| format!("[{}]", fmt_list(row)))
            .collect::<Vec<_>>()
            .join(","),
        json_f64_prec(blocking, 1),
        json_f64_prec(insert_speedup, 2),
        fmt_list(&mutate_qps_list),
        json_f64_prec(mutate_speedup, 2),
    );
    std::fs::write("BENCH_pipeline.json", &json).expect("write BENCH_pipeline.json");
    println!("\nwrote BENCH_pipeline.json");

    println!();
    if fast {
        println!(
            "RESULT: SMOKE — fast mode; pipelined/blocking = {insert_speedup:.2}x, \
             batched/per-op updates = {mutate_speedup:.2}x."
        );
    } else if insert_speedup >= 2.0 && mutate_speedup >= 4.0 {
        println!(
            "RESULT: PASS — depth>=16 pipelining sustains {insert_speedup:.2}x the blocking \
             client; batched updates run {mutate_speedup:.2}x the per-op path."
        );
    } else {
        println!(
            "RESULT: WARNING — pipelined/blocking = {insert_speedup:.2}x (want >= 2x), \
             batched/per-op = {mutate_speedup:.2}x (want >= 4x); rerun on an idle machine."
        );
    }
}
