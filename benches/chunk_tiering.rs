//! Hot-budget sweep for the two-tier ChunkStore: sample latency and
//! resident memory as the hot budget shrinks from "all of it" to 10% of
//! the inserted bytes.
//!
//! Each measured op is one table sample plus resolving every chunk the
//! sampled item references — the exact server-side work `sampled_to_wire`
//! does before a reply leaves, so the hot/cold comparison captures what a
//! client actually feels. The acceptance shape: at a 10% hot budget the
//! round-trip stays byte-identical, cold p99 stays within a small factor
//! of hot p50 (page-cache read + CRC + decode, not a disk seek), and RSS
//! tracks the hot budget instead of the full data set.
//!
//! Run: `cargo bench --bench chunk_tiering`
//! (REVERB_BENCH_FAST=1 for the CI quick pass; emits BENCH_tiering.json.)

use reverb::core::table::TableConfig;
use reverb::net::server::Server;
use reverb::util::bench::{fast_mode, print_row, random_step};
use reverb::util::rng::Pcg32;
use reverb::util::stats::{json_f64_prec, Samples};
use reverb::{Client, Compression, WriterOptions};
use std::collections::HashMap;
use std::time::Instant;

/// Resident set size in bytes from `/proc/self/status` (0 off-linux).
fn rss_bytes() -> u64 {
    let status = std::fs::read_to_string("/proc/self/status").unwrap_or_default();
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmRSS:") {
            let kb: u64 = rest
                .trim()
                .trim_end_matches("kB")
                .trim()
                .parse()
                .unwrap_or(0);
            return kb * 1024;
        }
    }
    0
}

struct Row {
    hot_pct: u64,
    p50_us: f64,
    p99_us: f64,
    demotions: u64,
    rehydrations: u64,
    cold_bytes: u64,
    rss_delta_mb: f64,
    byte_identical: bool,
}

fn main() {
    let fast = fast_mode();
    let floats = 16_384; // 64 kB per item, incompressible
    let n_items = if fast { 64 } else { 512 };
    let samples = if fast { 2_000 } else { 20_000 };
    let total_bytes = (n_items * floats * 4) as u64;
    let dir = std::env::temp_dir().join(format!("rvb_bench_tier_{}", std::process::id()));
    let rss_base = rss_bytes();

    println!(
        "# Chunk tiering: table sample + chunk resolve vs hot budget, {n_items} x 64 kB items \
         ({} MB inserted), {samples} samples",
        total_bytes / (1024 * 1024)
    );
    println!("| hot budget | p50 (us) | p99 (us) | demotions | rehydrations | cold MB | RSS delta MB |");
    println!("|---|---|---|---|---|---|---|");

    let mut rng = Pcg32::new(0x5eed, 17);
    let mut rows: Vec<Row> = Vec::new();
    for &hot_pct in &[100u64, 50, 10] {
        // 100% gets headroom so nothing ever demotes (the hot baseline).
        let hot_bytes = if hot_pct == 100 {
            total_bytes * 2
        } else {
            total_bytes * hot_pct / 100
        };
        let d = dir.join(hot_pct.to_string());
        std::fs::create_dir_all(&d).unwrap();
        let server = Server::builder()
            .table(TableConfig::uniform_replay("t", n_items * 2))
            .chunk_hot_bytes(hot_bytes)
            .chunk_cold_dir(&d)
            .serve_in_proc()
            .unwrap();
        let client = Client::connect(server.in_proc_addr()).unwrap();
        let mut w = client
            .writer(WriterOptions::default().with_compression(Compression::None))
            .unwrap();
        for _ in 0..n_items {
            w.append(random_step(floats, &mut rng)).unwrap();
            w.create_item("t", 1, 1.0).unwrap();
        }
        w.flush().unwrap();

        // Capture probe chunks' encoded bytes while hot, then demote.
        let table = server.table("t").unwrap();
        let (items, _, _) = table.snapshot();
        let mut probes: HashMap<u64, Vec<u8>> = HashMap::new();
        for item in items.iter().step_by((n_items / 8).max(1)) {
            for h in &item.chunks {
                let chunk = h.resolve().unwrap();
                let mut buf = Vec::new();
                chunk.encode(&mut buf).unwrap();
                probes.insert(chunk.key, buf);
            }
        }
        server.chunk_store().run_maintenance();

        let mut lat = Samples::new();
        for r in 0..samples {
            // Periodic re-demotion keeps the budget enforced while
            // rehydrations churn chunks back in.
            if r % 256 == 0 {
                server.chunk_store().run_maintenance();
            }
            let t0 = Instant::now();
            let s = table.sample(None).unwrap();
            for h in &s.item.chunks {
                std::hint::black_box(h.resolve().unwrap());
            }
            lat.add(t0.elapsed().as_secs_f64() * 1e6);
        }
        server.chunk_store().run_maintenance();

        // Byte-identity through however many demote/rehydrate cycles the
        // probes went through.
        let byte_identical = probes.iter().all(|(key, want)| {
            let mut got = Vec::new();
            let chunk = server.chunk_store().get(*key).unwrap().resolve().unwrap();
            chunk.encode(&mut got).unwrap();
            got == *want
        });

        let stats = server.chunk_store().stats();
        let rss_delta_mb =
            rss_bytes().saturating_sub(rss_base) as f64 / (1024.0 * 1024.0);
        let row = Row {
            hot_pct,
            p50_us: lat.percentile(50.0),
            p99_us: lat.percentile(99.0),
            demotions: stats.demotions,
            rehydrations: stats.rehydrations,
            cold_bytes: stats.cold_bytes,
            rss_delta_mb,
            byte_identical,
        };
        print_row(&[
            format!("{hot_pct}%"),
            format!("{:.1}", row.p50_us),
            format!("{:.1}", row.p99_us),
            row.demotions.to_string(),
            row.rehydrations.to_string(),
            format!("{:.1}", row.cold_bytes as f64 / (1024.0 * 1024.0)),
            format!("{:.0}", rss_delta_mb),
        ]);
        rows.push(row);
        drop(client);
        drop(server);
    }
    std::fs::remove_dir_all(&dir).ok();

    let results: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "    {{\"hot_pct\": {}, \"sample_p50_us\": {}, \"sample_p99_us\": {}, \
                 \"demotions\": {}, \"rehydrations\": {}, \"cold_bytes\": {}, \
                 \"rss_delta_mb\": {}, \"byte_identical\": {}}}",
                r.hot_pct,
                json_f64_prec(r.p50_us, 2),
                json_f64_prec(r.p99_us, 2),
                r.demotions,
                r.rehydrations,
                r.cold_bytes,
                json_f64_prec(r.rss_delta_mb, 1),
                r.byte_identical
            )
        })
        .collect();
    let json = format!(
        "{{\n  \"bench\": \"chunk_tiering\",\n  \"fast\": {fast},\n  \
         \"chunk_bytes\": {},\n  \"n_items\": {n_items},\n  \"samples\": {samples},\n  \
         \"results\": [\n{}\n  ]\n}}\n",
        floats * 4,
        results.join(",\n")
    );
    std::fs::write("BENCH_tiering.json", &json).expect("write BENCH_tiering.json");
    println!("\nwrote BENCH_tiering.json");

    // Acceptance guards, reported not enforced (CI uploads the JSON).
    let hot_p50 = rows[0].p50_us;
    let cold = rows.last().unwrap();
    if !cold.byte_identical {
        println!("RESULT: FAIL — cold round-trip not byte-identical at 10% hot budget.");
    } else if cold.demotions == 0 || cold.rehydrations == 0 {
        println!("RESULT: WARNING — 10% budget never exercised the cold tier; sweep too small.");
    } else if hot_p50 > 0.0 && cold.p99_us <= hot_p50 * 10.0 {
        println!(
            "RESULT: PASS — 10%-budget p99 {:.1} us within 10x of hot p50 {:.1} us; \
             byte-identical through {} demotions / {} rehydrations.",
            cold.p99_us, hot_p50, cold.demotions, cold.rehydrations
        );
    } else {
        println!(
            "RESULT: WARNING — 10%-budget p99 {:.1} us vs hot p50 {:.1} us exceeds 10x; \
             inspect cold-read path.",
            cold.p99_us, hot_p50
        );
    }
}
