//! Replay fabric sweep (DESIGN.md §14): one `reverb+pool://` facade over
//! 1 / 2 / 4 independent in-proc members, measured through the *whole*
//! client stack — writers consistent-hash their items across members,
//! samplers draw members mass-weighted, and every fleet worker dials the
//! single pool address exactly as it would dial one server.
//!
//! Three workloads per member count: insert-only, sample-only (prefilled
//! tables), and the mixed writer/sampler loop. Members are independent
//! servers (§3.6 sharding), so aggregate throughput should rise with the
//! member count until the bench box itself saturates; the facade's routing
//! overhead is the thing this sweep keeps honest.
//!
//! Run: `cargo bench --bench pool_fabric`
//! (REVERB_BENCH_FAST=1 for the CI quick pass.) Emits `BENCH_fabric.json`
//! for the CI perf trajectory.

use reverb::core::table::TableConfig;
use reverb::net::server::Server;
use reverb::util::bench::*;
use reverb::util::stats::{fmt_qps, json_f64_prec};
use reverb::{Fabric, FabricOptions};

const PAYLOAD_FLOATS: usize = 100; // 400 B, the paper's small-payload point
const PREFILL_ITEMS: usize = 2_000;

/// N independent members with unique in-proc names per sweep point, each
/// prefilled so sample-only workers have mass to draw from immediately.
fn start_members(n: usize) -> (Vec<Server>, Vec<String>) {
    let servers: Vec<Server> = (0..n)
        .map(|i| {
            Server::builder()
                .table(TableConfig::uniform_replay("t", 4_000_000))
                .in_proc_name(format!("bench-fabric-{n}-{i}"))
                .serve_in_proc()
                .unwrap()
        })
        .collect();
    for s in &servers {
        prefill_table(&s.table("t").unwrap(), PREFILL_ITEMS, PAYLOAD_FLOATS);
    }
    let addrs = servers.iter().map(|s| s.in_proc_addr()).collect();
    (servers, addrs)
}

fn main() {
    let fast = fast_mode();
    let member_counts: &[usize] = if fast { &[1, 2] } else { &[1, 2, 4] };
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(2);
    let clients = if fast { 4 } else { (2 * cores).clamp(4, 16) };

    println!(
        "# Replay fabric: {clients} clients on one reverb+pool:// address, \
         members x workload QPS"
    );
    print_row(&[
        "members".into(),
        "insert/s".into(),
        "sample/s".into(),
        "mixed/s".into(),
    ]);
    print_row(&["---".into(), "---".into(), "---".into(), "---".into()]);

    let mut rows: Vec<(usize, f64, f64, f64)> = Vec::new();
    for &n in member_counts {
        let (servers, addrs) = start_members(n);
        let fabric = Fabric::connect(&addrs, FabricOptions::default()).unwrap();
        let pool = fabric.pool_addr();

        let ins = run_insert_clients(&pool, &["t".into()], clients, PAYLOAD_FLOATS, window());
        let smp = run_sample_clients(&pool, "t", clients, PAYLOAD_FLOATS, window(), 4);
        let mix = run_mixed_clients(&pool, "t", clients, PAYLOAD_FLOATS, window());

        // Sanity: consistent hashing spread the inserts over every member.
        let sizes: Vec<usize> = servers
            .iter()
            .map(|s| s.table("t").unwrap().size())
            .collect();
        assert!(
            sizes.iter().all(|&s| s > PREFILL_ITEMS),
            "a member received no routed inserts: {sizes:?}"
        );

        print_row(&[
            n.to_string(),
            fmt_qps(ins.qps()),
            fmt_qps(smp.qps()),
            fmt_qps(mix.qps()),
        ]);
        rows.push((n, ins.qps(), smp.qps(), mix.qps()));
        drop(fabric);
        drop(servers);
    }

    let base = rows[0];
    let last = *rows.last().unwrap();
    let insert_scaling = last.1 / base.1.max(1.0);
    let sample_scaling = last.2 / base.2.max(1.0);

    let results: Vec<String> = rows
        .iter()
        .map(|(n, i, s, m)| {
            format!(
                "    {{\"members\": {n}, \"insert_qps\": {}, \"sample_qps\": {}, \
                 \"mixed_qps\": {}}}",
                json_f64_prec(*i, 1),
                json_f64_prec(*s, 1),
                json_f64_prec(*m, 1)
            )
        })
        .collect();
    let json = format!(
        "{{\n  \"bench\": \"pool_fabric\",\n  \"clients\": {clients},\n  \
         \"payload_floats\": {PAYLOAD_FLOATS},\n  \"fast\": {fast},\n  \
         \"insert_scaling\": {},\n  \"sample_scaling\": {},\n  \
         \"results\": [\n{}\n  ]\n}}\n",
        json_f64_prec(insert_scaling, 2),
        json_f64_prec(sample_scaling, 2),
        results.join(",\n")
    );
    std::fs::write("BENCH_fabric.json", &json).expect("write BENCH_fabric.json");
    println!("\nwrote BENCH_fabric.json");

    println!();
    if fast {
        println!(
            "RESULT: SMOKE — fast mode; {} -> {} members scaled inserts \
             {insert_scaling:.2}x, samples {sample_scaling:.2}x.",
            base.0, last.0
        );
    } else if insert_scaling >= 1.2 {
        println!(
            "RESULT: PASS — {} members sustain {insert_scaling:.2}x the single-member \
             insert rate through one pool address ({} -> {}).",
            last.0,
            fmt_qps(base.1),
            fmt_qps(last.1)
        );
    } else {
        println!(
            "RESULT: WARNING — insert scaling {insert_scaling:.2}x at {} members \
             (want >= 1.2x); rerun on an idle multi-core box.",
            last.0
        );
    }
}
