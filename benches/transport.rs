//! Transport comparison: insert/sample QPS of the zero-copy in-process
//! backend vs TCP loopback, same server code, same client code — only the
//! `Client::connect` endpoint differs.
//!
//! The paper (§2, §5) argues Reverb's ceilings live in the tables, not the
//! transport; GEAR-style shared-memory data paths show how much headroom a
//! copy-free path buys for co-located actors/learners. Expected result:
//! in-process insert QPS ≥ TCP insert QPS at every payload size (it skips
//! frame encode/decode and syscalls entirely), with the gap widening as
//! payloads grow.
//!
//! Run: `cargo bench --bench transport`
//! (REVERB_BENCH_FAST=1 for a quick pass.)

use reverb::core::table::TableConfig;
use reverb::net::server::Server;
use reverb::util::bench::*;
use reverb::util::stats::{fmt_bps, fmt_qps};
use std::time::Duration;

const CLIENTS: usize = 4;

fn window_for(fast: bool) -> Duration {
    if fast {
        Duration::from_millis(300)
    } else {
        Duration::from_millis(1000)
    }
}

/// One (backend, payload) insert measurement on a fresh server.
fn insert_qps(in_proc: bool, floats: usize, window: Duration) -> Throughput {
    let builder = Server::builder().table(TableConfig::uniform_replay("t", 200_000));
    let (server, addr) = if in_proc {
        let s = builder.serve_in_proc().unwrap();
        let a = s.in_proc_addr();
        (s, a)
    } else {
        let s = builder.bind("127.0.0.1:0").unwrap();
        let a = format!("tcp://{}", s.local_addr());
        (s, a)
    };
    let t = run_insert_clients(&addr, &["t".to_string()], CLIENTS, floats, window);
    drop(server);
    t
}

/// One (backend, payload) sample measurement on a pre-filled server.
fn sample_qps(in_proc: bool, floats: usize, window: Duration) -> Throughput {
    let builder = Server::builder().table(TableConfig::uniform_replay("t", 100_000));
    let (server, addr) = if in_proc {
        let s = builder.serve_in_proc().unwrap();
        let a = s.in_proc_addr();
        (s, a)
    } else {
        let s = builder.bind("127.0.0.1:0").unwrap();
        let a = format!("tcp://{}", s.local_addr());
        (s, a)
    };
    prefill_table(&server.table("t").unwrap(), 1_000, floats);
    let t = run_sample_clients(&addr, "t", CLIENTS, floats, window, 8);
    drop(server);
    t
}

fn main() {
    let fast = fast_mode();
    let window = window_for(fast);
    let payloads: &[(usize, &str)] = if fast {
        &[(100, "400B"), (10_000, "40kB")]
    } else {
        PAYLOAD_SIZES
    };

    println!("# Transport: zero-copy in-process vs TCP loopback ({CLIENTS} clients)");
    println!("| op | payload | tcp QPS | in-proc QPS | in-proc/tcp | in-proc BPS |");
    println!("|---|---|---|---|---|---|");

    let mut all_hold = true;
    for &(floats, label) in payloads {
        let tcp = insert_qps(false, floats, window);
        let ip = insert_qps(true, floats, window);
        let ratio = ip.qps() / tcp.qps().max(1.0);
        if ip.qps() < tcp.qps() {
            all_hold = false;
        }
        print_row(&[
            "insert".into(),
            label.into(),
            fmt_qps(tcp.qps()),
            fmt_qps(ip.qps()),
            format!("{ratio:.2}x"),
            fmt_bps(ip.bps()),
        ]);
    }
    for &(floats, label) in payloads {
        let tcp = sample_qps(false, floats, window);
        let ip = sample_qps(true, floats, window);
        let ratio = ip.qps() / tcp.qps().max(1.0);
        print_row(&[
            "sample".into(),
            label.into(),
            fmt_qps(tcp.qps()),
            fmt_qps(ip.qps()),
            format!("{ratio:.2}x"),
            fmt_bps(ip.bps()),
        ]);
    }

    println!();
    if all_hold {
        println!("RESULT: PASS — in-process insert QPS >= TCP-loopback insert QPS at every payload size.");
    } else {
        println!("RESULT: WARNING — TCP beat in-process on at least one insert payload; rerun on an idle machine.");
    }
}
