//! TrajectoryWriter vs legacy Writer insert throughput.
//!
//! The column-oriented write path (DESIGN.md §9) chunks every column
//! independently and ships per-column slice lists in v2 item frames, where
//! the legacy writer cuts one multi-field chunk per step and ships a flat
//! span. This bench quantifies what that flexibility costs (or saves) on
//! the §5-style insert workload: same total payload per step, split across
//! 1 / 4 / 16 columns, both writers, zero-copy in-process transport so the
//! measurement is writer + table work rather than socket work.
//!
//! Run: `cargo bench --bench trajectory_writer`
//! (REVERB_BENCH_FAST=1 for the CI quick pass; emits BENCH_trajectory.json.)

use reverb::core::table::TableConfig;
use reverb::net::server::Server;
use reverb::util::bench::*;
use reverb::util::stats::{fmt_qps, json_f64_prec};
use std::time::Duration;

const COLUMN_COUNTS: &[usize] = &[1, 4, 16];
/// Total f32s per appended step (≈ 4 kB), split across the columns.
const FLOATS_PER_STEP: usize = 1_024;

fn window_for(fast: bool) -> Duration {
    if fast {
        Duration::from_millis(300)
    } else {
        Duration::from_millis(1200)
    }
}

/// One `(writer kind, num_columns)` measurement on a fresh in-proc server.
fn measure(trajectory: bool, num_columns: usize, clients: usize, window: Duration) -> Throughput {
    let server = Server::builder()
        .table(TableConfig::uniform_replay("t", 1_000_000))
        .serve_in_proc()
        .unwrap();
    let addr = server.in_proc_addr();
    let t = if trajectory {
        run_trajectory_insert_clients(&addr, "t", clients, FLOATS_PER_STEP, num_columns, window)
    } else {
        run_row_insert_clients(&addr, "t", clients, FLOATS_PER_STEP, num_columns, window)
    };
    drop(server);
    t
}

fn main() {
    let fast = fast_mode();
    let window = window_for(fast);
    let clients = if fast { 2 } else { 4 };

    println!(
        "# TrajectoryWriter vs legacy Writer: insert QPS, {clients} clients, \
         {FLOATS_PER_STEP} f32/step split across N columns (in-proc)"
    );
    println!("| columns | legacy writer | trajectory writer | trajectory/legacy |");
    println!("|---|---|---|---|");

    let mut rows: Vec<(usize, f64, f64)> = Vec::new();
    for &cols in COLUMN_COUNTS {
        let legacy = measure(false, cols, clients, window).qps();
        let traj = measure(true, cols, clients, window).qps();
        rows.push((cols, legacy, traj));
        print_row(&[
            cols.to_string(),
            fmt_qps(legacy),
            fmt_qps(traj),
            format!("{:.2}x", traj / legacy),
        ]);
    }

    // The trajectory path sends one chunk per column per step here
    // (chunk_length 1); it should stay within a small factor of the
    // legacy single-chunk path at 1 column and degrade gracefully as
    // column count grows. Guard the ratio: a zero legacy measurement
    // (e.g. connect failure on a loaded runner) must not write inf/NaN
    // into the JSON artifact.
    let single_col_ratio = if rows[0].1 > 0.0 {
        rows[0].2 / rows[0].1
    } else {
        0.0
    };

    let results: Vec<String> = rows
        .iter()
        .map(|(c, l, t)| {
            format!(
                "    {{\"columns\": {c}, \"legacy_qps\": {}, \"trajectory_qps\": {}}}",
                json_f64_prec(*l, 1),
                json_f64_prec(*t, 1)
            )
        })
        .collect();
    let json = format!(
        "{{\n  \"bench\": \"trajectory_writer\",\n  \"mode\": \"insert_qps_in_proc\",\n  \
         \"clients\": {clients},\n  \"floats_per_step\": {FLOATS_PER_STEP},\n  \
         \"fast\": {fast},\n  \"single_column_ratio\": {},\n  \
         \"results\": [\n{}\n  ]\n}}\n",
        json_f64_prec(single_col_ratio, 3),
        results.join(",\n")
    );
    std::fs::write("BENCH_trajectory.json", &json).expect("write BENCH_trajectory.json");
    println!("\nwrote BENCH_trajectory.json");

    if single_col_ratio > 0.5 {
        println!(
            "RESULT: PASS — single-column trajectory path within 2x of the legacy writer \
             ({:.2}x).",
            single_col_ratio
        );
    } else {
        println!(
            "RESULT: WARNING — single-column trajectory path at {:.2}x of legacy; \
             investigate per-column chunking overhead.",
            single_col_ratio
        );
    }
}
