//! Checkpoint gate-pause scaling (§3.7 vs DESIGN.md §10).
//!
//! The paper's checkpoint blocks *all* insert/sample/update/delete traffic
//! while the full table serializes, so the pause grows linearly with table
//! size. The incremental persist subsystem replaces that with a
//! constant-time journal rotation: the gate pause should stay flat from
//! 10k to 1M items while the legacy full-snapshot pause keeps scaling.
//!
//! For each table size and each mode this harness measures the
//! steady-state checkpoint (min of several runs, a ~100-item delta since
//! the previous one for the incremental mode):
//!
//! - **pause**: how long the request gate was closed
//!   (`Server::last_checkpoint_pause`) — the number that must stay flat;
//! - **total**: wall time of the whole checkpoint RPC (for incremental
//!   this includes waiting for the background fsync, which happens off
//!   the gate).
//!
//! Emits `BENCH_checkpoint.json`, uploaded by CI next to the fig7 and
//! trajectory artifacts. Run: `cargo bench --bench checkpoint_pause`
//! (REVERB_BENCH_FAST=1 for the CI quick pass).

use reverb::core::chunk::{Chunk, Compression};
use reverb::core::item::Item;
use reverb::core::table::TableConfig;
use reverb::net::server::{PersistMode, Server};
use reverb::util::bench::{fast_mode, print_row};
use reverb::util::stats::json_f64_prec;
use reverb::Tensor;
use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

const SIZES: &[usize] = &[10_000, 100_000, 1_000_000];

struct Measure {
    pause: Duration,
    total: Duration,
    /// First checkpoint after the bulk load (incremental: includes the
    /// writer catching up on the whole journal, still off the gate).
    first_total: Duration,
}

fn shared_chunk() -> Arc<Chunk> {
    let steps = vec![vec![Tensor::from_f32(&[4], &[1.0, 2.0, 3.0, 4.0]).unwrap()]];
    Arc::new(Chunk::from_steps(1, 0, &steps, Compression::None).unwrap())
}

/// Measure one mode at one size. Items share a single chunk, so the cost
/// under measurement is the per-item metadata walk/serialization — the
/// part that scales with item count.
fn run_mode(incremental: bool, n: usize, dir: &Path, reps: usize) -> Measure {
    std::fs::remove_dir_all(dir).ok();
    std::fs::create_dir_all(dir).unwrap();
    let mut builder = Server::builder()
        .table(TableConfig::uniform_replay("t", n + 10_000))
        .checkpoint_dir(dir);
    if incremental {
        builder = builder.persist_mode(PersistMode::incremental());
    }
    let server = builder.serve_in_proc().unwrap();
    let table = server.table("t").unwrap();
    let chunk = shared_chunk();
    for k in 1..=n as u64 {
        table
            .insert_or_assign(
                Item::new(k, "t", 1.0, vec![chunk.clone()], 0, 1).unwrap(),
                None,
            )
            .unwrap();
    }

    let start = Instant::now();
    server.checkpoint().expect("first checkpoint");
    let first_total = start.elapsed();

    // Steady state: a small delta between checkpoints, min over reps.
    let mut pause = Duration::MAX;
    let mut total = Duration::MAX;
    let mut next = n as u64;
    for _ in 0..reps {
        for _ in 0..100 {
            next += 1;
            table
                .insert_or_assign(
                    Item::new(next, "t", 1.0, vec![chunk.clone()], 0, 1).unwrap(),
                    None,
                )
                .unwrap();
        }
        let start = Instant::now();
        server.checkpoint().expect("steady-state checkpoint");
        total = total.min(start.elapsed());
        pause = pause.min(server.last_checkpoint_pause());
    }

    // Correctness spot-check: the chain restores to the live item count.
    if incremental {
        let live = table.size();
        let dst = Arc::new(reverb::core::table::Table::new(TableConfig::uniform_replay(
            "t",
            n + 10_000,
        )));
        let restored = reverb::core::checkpoint::load(
            &dir.join(reverb::persist::MANIFEST_NAME),
            &[dst.clone()],
            &reverb::ChunkStore::new(),
        )
        .expect("restore");
        assert_eq!(restored, live, "incremental restore item count");
    }
    drop(server);
    std::fs::remove_dir_all(dir).ok();
    Measure {
        pause,
        total,
        first_total,
    }
}

fn ms(d: Duration) -> f64 {
    d.as_secs_f64() * 1e3
}

fn main() {
    let fast = fast_mode();
    // Fast mode keeps the full 10k -> 1M sweep (the scaling claim needs
    // the endpoints) but takes a single steady-state measurement per
    // point, so the CI smoke stays a handful of snapshots.
    let reps = if fast { 1 } else { 5 };
    let tmp = std::env::temp_dir().join(format!("reverb_bench_ckpt_{}", std::process::id()));

    println!("# Checkpoint gate pause vs table size (§3.7 vs incremental §10)");
    println!("| items | full pause | full total | incr pause | incr total | incr 1st total |");
    println!("|---|---|---|---|---|---|");
    let mut rows: Vec<(usize, Measure, Measure)> = Vec::new();
    for &n in SIZES {
        let full = run_mode(false, n, &tmp.join("full"), reps);
        let incr = run_mode(true, n, &tmp.join("incr"), reps);
        print_row(&[
            n.to_string(),
            format!("{:.3} ms", ms(full.pause)),
            format!("{:.3} ms", ms(full.total)),
            format!("{:.3} ms", ms(incr.pause)),
            format!("{:.3} ms", ms(incr.total)),
            format!("{:.1} ms", ms(incr.first_total)),
        ]);
        rows.push((n, full, incr));
    }

    // Flatness: incremental pause at the largest size within 2x of the
    // smallest size (with a 0.5 ms noise floor — "flat" means the pause
    // stays sub-millisecond-scale no matter the table size). Legacy must
    // keep scaling with size.
    let floor = 0.5f64; // ms
    let incr_small = ms(rows.first().unwrap().2.pause).max(floor);
    let incr_large = ms(rows.last().unwrap().2.pause);
    let incr_flat = incr_large <= 2.0 * incr_small;
    let full_small = ms(rows.first().unwrap().1.pause).max(1e-3);
    let full_large = ms(rows.last().unwrap().1.pause);
    let full_scaling = full_large / full_small;
    let legacy_scales = full_scaling > 4.0;

    let results: Vec<String> = rows
        .iter()
        .map(|(n, full, incr)| {
            format!(
                "    {{\"items\": {n}, \"full_pause_ms\": {}, \"full_total_ms\": {}, \
                 \"incr_pause_ms\": {}, \"incr_total_ms\": {}, \"incr_first_total_ms\": {}}}",
                json_f64_prec(ms(full.pause), 4),
                json_f64_prec(ms(full.total), 4),
                json_f64_prec(ms(incr.pause), 4),
                json_f64_prec(ms(incr.total), 4),
                json_f64_prec(ms(incr.first_total), 4)
            )
        })
        .collect();
    let json = format!(
        "{{\n  \"bench\": \"checkpoint_pause\",\n  \"fast\": {fast},\n  \
         \"incremental_flat_within_2x\": {incr_flat},\n  \
         \"legacy_pause_scaling_10k_to_1m\": {},\n  \
         \"results\": [\n{}\n  ]\n}}\n",
        json_f64_prec(full_scaling, 1),
        results.join(",\n")
    );
    std::fs::write("BENCH_checkpoint.json", &json).expect("write BENCH_checkpoint.json");
    println!("\nwrote BENCH_checkpoint.json");

    println!();
    if incr_flat && legacy_scales {
        println!(
            "RESULT: PASS — incremental pause flat ({:.3} ms -> {:.3} ms, 10k -> 1M items) \
             while the legacy full-snapshot pause scales {:.0}x.",
            ms(rows.first().unwrap().2.pause),
            incr_large,
            full_scaling
        );
    } else if incr_flat {
        println!(
            "RESULT: WARNING — legacy pause only scaled {full_scaling:.1}x \
             (expected ~linear); rerun on an idle box."
        );
    } else {
        println!(
            "RESULT: WARNING — incremental pause not flat ({incr_small:.3} ms -> \
             {incr_large:.3} ms); rerun on an idle box."
        );
    }
    std::fs::remove_dir_all(&tmp).ok();
}
