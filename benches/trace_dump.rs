//! End-to-end flight-recorder check (DESIGN.md §15): boot a server with
//! the metrics listener, push traced traffic through a pipelined client,
//! fetch `/trace`, and validate the dump is well-formed Chrome
//! trace-event JSON carrying the expected stage names. Saves the raw
//! dump to `TRACE_dump.json` (CI uploads it next to the `BENCH_*.json`
//! artifacts) and a summary to `BENCH_trace_dump.json`.
//!
//! Run: `cargo bench --bench trace_dump`

use reverb::core::table::TableConfig;
use reverb::net::trace::TraceContext;
use reverb::net::wire::{Message, WireItem};
use reverb::util::bench::*;
use reverb::util::rng::Pcg32;
use reverb::{Chunk, Compression, Pipeline, Server};
use std::collections::BTreeSet;
use std::io::{Read, Write};
use std::sync::Arc;

const PAYLOAD_FLOATS: usize = 50;
const BATCHES: usize = 64;
const BATCH: usize = 8;

fn mk_op(key: u64, rng: &mut Pcg32) -> (Arc<Chunk>, WireItem) {
    let steps = vec![random_step(PAYLOAD_FLOATS, rng)];
    let chunk = Arc::new(Chunk::from_steps(key, 0, &steps, Compression::None).unwrap());
    let item = WireItem {
        key: key | (1 << 62),
        table: "t".into(),
        priority: 1.0,
        chunk_keys: vec![key],
        offset: 0,
        length: 1,
        times_sampled: 0,
        columns: None,
    };
    (chunk, item)
}

/// Minimal single-pass JSON well-formedness scanner (the offline crate
/// set has no serde): validates the full value grammar, nothing more.
struct Scan<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Scan<'a> {
    fn ws(&mut self) {
        while matches!(self.b.get(self.i), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.b.get(self.i) == Some(&c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at byte {}", c as char, self.i))
        }
    }

    fn string(&mut self) -> Result<(), String> {
        self.eat(b'"')?;
        loop {
            match self.b.get(self.i) {
                Some(b'"') => {
                    self.i += 1;
                    return Ok(());
                }
                Some(b'\\') => self.i += 2,
                Some(_) => self.i += 1,
                None => return Err("unterminated string".into()),
            }
        }
    }

    fn value(&mut self) -> Result<(), String> {
        self.ws();
        match self.b.get(self.i) {
            Some(b'{') => {
                self.i += 1;
                self.ws();
                if self.b.get(self.i) == Some(&b'}') {
                    self.i += 1;
                    return Ok(());
                }
                loop {
                    self.ws();
                    self.string()?;
                    self.ws();
                    self.eat(b':')?;
                    self.value()?;
                    self.ws();
                    match self.b.get(self.i) {
                        Some(b',') => self.i += 1,
                        Some(b'}') => {
                            self.i += 1;
                            return Ok(());
                        }
                        _ => return Err(format!("bad object at byte {}", self.i)),
                    }
                }
            }
            Some(b'[') => {
                self.i += 1;
                self.ws();
                if self.b.get(self.i) == Some(&b']') {
                    self.i += 1;
                    return Ok(());
                }
                loop {
                    self.value()?;
                    self.ws();
                    match self.b.get(self.i) {
                        Some(b',') => self.i += 1,
                        Some(b']') => {
                            self.i += 1;
                            return Ok(());
                        }
                        _ => return Err(format!("bad array at byte {}", self.i)),
                    }
                }
            }
            Some(b'"') => self.string(),
            Some(b't') => self.lit("true"),
            Some(b'f') => self.lit("false"),
            Some(b'n') => self.lit("null"),
            Some(_) => {
                let start = self.i;
                while matches!(
                    self.b.get(self.i),
                    Some(b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
                ) {
                    self.i += 1;
                }
                if self.i == start {
                    Err(format!("bad value at byte {start}"))
                } else {
                    Ok(())
                }
            }
            None => Err("truncated".into()),
        }
    }

    fn lit(&mut self, s: &str) -> Result<(), String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(())
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }
}

fn validate_json(text: &str) -> Result<(), String> {
    let mut s = Scan {
        b: text.as_bytes(),
        i: 0,
    };
    s.value()?;
    s.ws();
    if s.i == s.b.len() {
        Ok(())
    } else {
        Err(format!("trailing bytes at {}", s.i))
    }
}

fn http_get(addr: &str, path: &str) -> String {
    let mut sock = std::net::TcpStream::connect(addr).expect("connect metrics listener");
    sock.write_all(
        format!("GET {path} HTTP/1.1\r\nHost: reverb\r\nConnection: close\r\n\r\n").as_bytes(),
    )
    .unwrap();
    let mut raw = Vec::new();
    sock.read_to_end(&mut raw).unwrap();
    let text = String::from_utf8_lossy(&raw).into_owned();
    let (head, body) = text.split_once("\r\n\r\n").expect("http head");
    assert!(head.starts_with("HTTP/1.1 200"), "{path} failed: {head}");
    body.to_string()
}

fn main() {
    let server = Server::builder()
        .table(TableConfig::uniform_replay("t", 100_000))
        .metrics_addr("127.0.0.1:0")
        .bind("127.0.0.1:0")
        .unwrap();
    let addr = format!("tcp://{}", server.local_addr());
    let scrape = server.metrics_addr().unwrap().to_string();

    // Traced traffic: every batch stamped, so the dump carries full
    // client→server span chains.
    let pipe = Pipeline::connect(&addr, 8).unwrap();
    let mut rng = Pcg32::new(0x7ace, 0xd00d);
    let mut next_key = 1u64;
    let mut outstanding = std::collections::VecDeque::new();
    for _ in 0..BATCHES {
        let mut chunks = Vec::with_capacity(BATCH);
        let mut items = Vec::with_capacity(BATCH);
        for _ in 0..BATCH {
            let (c, i) = mk_op(next_key, &mut rng);
            next_key += 1;
            chunks.push(c);
            items.push(i);
        }
        pipe.send_unacked(Message::InsertChunks { chunks }).unwrap();
        let c = pipe
            .submit(|id| Message::CreateItemBatch {
                id,
                items,
                timeout_ms: 30_000,
                trace: Some(TraceContext::generate()),
            })
            .unwrap();
        pipe.flush().unwrap();
        outstanding.push_back(c);
        while outstanding.len() >= 8 {
            outstanding.pop_front().unwrap().expect_batch().unwrap();
        }
    }
    while let Some(c) = outstanding.pop_front() {
        c.expect_batch().unwrap();
    }

    let dump = http_get(&scrape, "/trace");
    std::fs::write("TRACE_dump.json", &dump).expect("write TRACE_dump.json");

    if let Err(e) = validate_json(&dump) {
        println!("RESULT: FAIL — /trace is not well-formed JSON: {e}");
        std::process::exit(1);
    }
    if !dump.starts_with("{\"traceEvents\":[") {
        println!("RESULT: FAIL — /trace missing traceEvents envelope");
        std::process::exit(1);
    }
    let events = dump.matches("\"ph\":\"X\"").count();
    let stages: BTreeSet<&str> = [
        "decode", "queue", "gate", "lock", "execute", "journal", "flush", "submit",
        "client_flush", "reply", "pick", "reroute",
    ]
    .into_iter()
    .filter(|s| dump.contains(&format!("\"name\":\"{s}\"")))
    .collect();
    println!("# /trace: {events} spans, stages {stages:?}");

    let required = ["submit", "reply", "execute"];
    let missing: Vec<&str> = required
        .iter()
        .copied()
        .filter(|s| !stages.contains(s))
        .collect();

    let json = format!(
        "{{\"bench\":\"trace_dump\",\"batches\":{BATCHES},\"batch\":{BATCH},\
         \"spans\":{events},\"stages\":[{}],\"missing\":[{}]}}",
        stages
            .iter()
            .map(|s| format!("\"{s}\""))
            .collect::<Vec<_>>()
            .join(","),
        missing
            .iter()
            .map(|s| format!("\"{s}\""))
            .collect::<Vec<_>>()
            .join(","),
    );
    std::fs::write("BENCH_trace_dump.json", &json).expect("write BENCH_trace_dump.json");
    println!("wrote BENCH_trace_dump.json + TRACE_dump.json");

    if events == 0 {
        println!("RESULT: FAIL — traced traffic produced an empty flight recorder");
        std::process::exit(1);
    }
    if !missing.is_empty() {
        println!("RESULT: FAIL — dump missing expected stages: {missing:?}");
        std::process::exit(1);
    }
    println!(
        "RESULT: PASS — /trace parses as Chrome trace-event JSON; {events} spans across \
         {} stages.",
        stages.len()
    );
}
