//! Figure 6 reproduction: single-server SAMPLE throughput (BPS & QPS) vs
//! number of concurrent clients, payloads 400 B → 400 kB.
//!
//! Expected shape (§5.2): same linear-then-plateau scaling as Figure 5 but
//! with a ~10× higher QPS ceiling than inserting — the sample path batches
//! selections under one table-lock acquisition and decompresses outside
//! the lock, while inserts pay per-item selector/eviction/extension work.
//!
//! Run: `cargo bench --bench fig6_sample_scaling`

use reverb::core::table::TableConfig;
use reverb::net::server::Server;
use reverb::util::bench::*;
use reverb::util::stats::{fmt_bps, fmt_qps};

fn main() {
    println!("# Figure 6: sample scaling (clients are loopback threads)");
    println!("| payload | clients | QPS | BPS | per-client QPS |");
    println!("|---|---|---|---|---|");
    let mut peak: Vec<(String, f64, f64)> = Vec::new();
    for &(floats, label) in PAYLOAD_SIZES {
        // One pre-filled server per payload size.
        let server = Server::builder()
            .table(TableConfig::uniform_replay("t", 100_000))
            .bind("127.0.0.1:0")
            .unwrap();
        prefill_table(&server.table("t").unwrap(), 2_000, floats);
        let addr = server.local_addr().to_string();

        let mut best_qps: f64 = 0.0;
        let mut best_bps: f64 = 0.0;
        for &clients in &client_counts() {
            let t = run_sample_clients(&addr, "t", clients, floats, window(), 16);
            best_qps = best_qps.max(t.qps());
            best_bps = best_bps.max(t.bps());
            print_row(&[
                label.to_string(),
                clients.to_string(),
                fmt_qps(t.qps()),
                fmt_bps(t.bps()),
                fmt_qps(t.qps() / clients as f64),
            ]);
        }
        peak.push((label.to_string(), best_qps, best_bps));
    }
    println!("\n## Peak sample throughput per payload (paper: ~600k items/s or ~11 GB/s, ≈10× insert QPS)");
    for (label, qps, bps) in peak {
        println!("  {label}: {} / {}", fmt_qps(qps), fmt_bps(bps));
    }
}
