//! Figure 7 / Appendix B reproduction: insert QPS vs clients when the load
//! is spread round-robin over 1, 2, 4, 8 tables on ONE server.
//!
//! The paper's hypothesis: the insert-QPS ceiling is Table-mutex
//! contention, so sharding the load across tables on the same server
//! should lift it (~200% improvement at 8 tables). Each client here writes
//! to `tables[client % n]`, mirroring the paper's round-robin
//! `create_item`.
//!
//! Run: `cargo bench --bench fig7_sharded_tables`

use reverb::core::table::TableConfig;
use reverb::net::server::Server;
use reverb::util::bench::*;
use reverb::util::stats::fmt_qps;

const FLOATS: usize = 100; // 400B payload isolates QPS from BPS limits

fn main() {
    println!("# Figure 7: insert QPS with the load sharded over N tables");
    println!("| tables | clients | QPS |");
    println!("|---|---|---|");
    let mut peaks = Vec::new();
    for &num_tables in &[1usize, 2, 4, 8] {
        let names: Vec<String> = (0..num_tables).map(|i| format!("t{i}")).collect();
        let mut best: f64 = 0.0;
        for &clients in &client_counts() {
            let mut builder = Server::builder();
            for n in &names {
                builder = builder.table(TableConfig::uniform_replay(n, 200_000));
            }
            let server = builder.bind("127.0.0.1:0").unwrap();
            let t = run_insert_clients(
                &server.local_addr().to_string(),
                &names,
                clients,
                FLOATS,
                window(),
            );
            best = best.max(t.qps());
            print_row(&[
                num_tables.to_string(),
                clients.to_string(),
                fmt_qps(t.qps()),
            ]);
        }
        peaks.push((num_tables, best));
    }
    println!("\n## Peak insert QPS by table count (paper: ~3x from 1 -> 8 tables)");
    let base = peaks[0].1;
    for (n, qps) in peaks {
        println!("  {n} tables: {} ({:.2}x vs 1 table)", fmt_qps(qps), qps / base);
    }
}
