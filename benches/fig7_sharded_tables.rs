//! Figure 7 reproduction — sharded tables behind ONE table name.
//!
//! The paper's hypothesis: the insert-QPS ceiling is Table-mutex
//! contention, so sharding lifts it (~200% at 8 shards). The seed bench
//! approximated this with N separate tables; since the `ShardedTable`
//! refactor (DESIGN.md §7) the server shards *one* table internally, so
//! this bench now measures the real thing: the same `insert_or_assign`
//! API, `num_shards` ∈ {1, 2, 4, 8}.
//!
//! Two measurements:
//! 1. **Direct table** (headline, recorded in `BENCH_fig7.json`): writer
//!    threads hammer `Table::insert_or_assign` with pre-built items — no
//!    transport, pure table-ceiling. This is the curve the shard count is
//!    supposed to move.
//! 2. **Full stack** (context): the same sweep through the server over the
//!    in-process transport.
//!
//! Run: `cargo bench --bench fig7_sharded_tables`
//! (REVERB_BENCH_FAST=1 for the CI quick pass.)

use reverb::core::chunk::{Chunk, Compression};
use reverb::core::item::Item;
use reverb::core::table::{Table, TableConfig};
use reverb::net::server::Server;
use reverb::util::bench::*;
use reverb::util::rng::Pcg32;
use reverb::util::stats::{fmt_qps, json_f64_prec};
use std::sync::Arc;
use std::time::Instant;

const SHARD_COUNTS: &[usize] = &[1, 2, 4, 8];

/// Pre-build `n` items with distinct keys for one writer thread. Tiny
/// payloads keep the measurement lock-bound, not memcpy-bound.
fn build_items(thread: u64, n: usize) -> Vec<Item> {
    let mut rng = Pcg32::new(0xF16_7, thread);
    (0..n)
        .map(|i| {
            let key = (thread << 40) | (i as u64 + 1);
            let vals = [rng.gen_f32(), rng.gen_f32(), rng.gen_f32(), rng.gen_f32()];
            let steps = vec![vec![reverb::Tensor::from_f32(&[4], &vals).unwrap()]];
            let chunk =
                Arc::new(Chunk::from_steps(key, 0, &steps, Compression::None).unwrap());
            Item::new(key, "t", 1.0, vec![chunk], 0, 1).unwrap()
        })
        .collect()
}

/// One direct-table run: `threads` writers insert their pre-built items
/// flat out; returns aggregate inserts/sec.
fn direct_insert_qps(shards: usize, threads: usize, per_thread: usize) -> f64 {
    let table = Arc::new(Table::new(
        TableConfig::uniform_replay("t", threads * per_thread + 1).with_shards(shards),
    ));
    let batches: Vec<Vec<Item>> = (0..threads as u64)
        .map(|t| build_items(t, per_thread))
        .collect();
    let start = Instant::now();
    let handles: Vec<_> = batches
        .into_iter()
        .map(|items| {
            let table = table.clone();
            std::thread::spawn(move || {
                for item in items {
                    table.insert_or_assign(item, None).unwrap();
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let wall = start.elapsed();
    assert_eq!(table.size(), threads * per_thread, "lost inserts");
    (threads * per_thread) as f64 / wall.as_secs_f64()
}

fn main() {
    let fast = fast_mode();
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(2);
    let threads = (2 * cores).max(4);
    let per_thread = if fast { 20_000 } else { 60_000 };
    let reps = if fast { 3 } else { 5 };

    println!("# Figure 7: one table name, {threads} writer threads, per-shard locking");
    println!("## Direct table (no transport): insert QPS vs num_shards");
    println!("| shards | inserts/s | vs 1 shard |");
    println!("|---|---|---|");
    let mut peaks: Vec<(usize, f64)> = Vec::new();
    for &shards in SHARD_COUNTS {
        let best = (0..reps)
            .map(|_| direct_insert_qps(shards, threads, per_thread))
            .fold(0.0f64, f64::max);
        peaks.push((shards, best));
        let base = peaks[0].1;
        print_row(&[
            shards.to_string(),
            fmt_qps(best),
            format!("{:.2}x", best / base),
        ]);
    }

    // Acceptance: throughput increases monotonically from 1 → 4 shards.
    let monotonic_1_to_4 = peaks
        .windows(2)
        .filter(|w| w[1].0 <= 4)
        .all(|w| w[1].1 >= w[0].1);

    // Machine-readable trajectory for CI (BENCH_fig7.json).
    let results: Vec<String> = peaks
        .iter()
        .map(|(s, q)| {
            format!(
                "    {{\"shards\": {s}, \"inserts_per_sec\": {}}}",
                json_f64_prec(*q, 1)
            )
        })
        .collect();
    let json = format!(
        "{{\n  \"bench\": \"fig7_sharded_tables\",\n  \"mode\": \"direct_table_insert\",\n  \
         \"threads\": {threads},\n  \"per_thread_inserts\": {per_thread},\n  \
         \"fast\": {fast},\n  \"monotonic_1_to_4\": {monotonic_1_to_4},\n  \
         \"results\": [\n{}\n  ]\n}}\n",
        results.join(",\n")
    );
    std::fs::write("BENCH_fig7.json", &json).expect("write BENCH_fig7.json");
    println!("\nwrote BENCH_fig7.json");

    // Full-stack context: same sweep through the server (in-proc clients).
    println!("\n## Full stack (in-process transport, {threads} clients)");
    println!("| shards | inserts/s |");
    println!("|---|---|");
    for &shards in SHARD_COUNTS {
        let server = Server::builder()
            .table(TableConfig::uniform_replay("t", 400_000).with_shards(shards))
            .serve_in_proc()
            .unwrap();
        let t = run_insert_clients(
            &server.in_proc_addr(),
            &["t".to_string()],
            threads,
            100,
            window(),
        );
        print_row(&[shards.to_string(), fmt_qps(t.qps())]);
        drop(server);
    }

    println!();
    if monotonic_1_to_4 {
        println!(
            "RESULT: PASS — direct insert throughput rises monotonically 1 -> 4 shards \
             ({} -> {}).",
            fmt_qps(peaks[0].1),
            fmt_qps(peaks[2].1)
        );
    } else {
        println!(
            "RESULT: WARNING — non-monotonic shard scaling {:?}; rerun on an idle multi-core box.",
            peaks
        );
    }
}
