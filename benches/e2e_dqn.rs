//! End-to-end pipeline benchmark: the full distributed-DQN stack (actors →
//! Reverb PER table → AOT learner) measured in train-steps/s and
//! env-steps/s. Requires `make artifacts`.
//!
//! Run: `cargo bench --bench e2e_dqn`

use reverb::coordinator::{run_dqn, DqnConfig};
use reverb::net::server::Server;

fn main() {
    if !reverb::runtime::can_execute_artifacts() {
        println!("SKIPPED: needs `make artifacts` + a real PJRT backend (DESIGN.md §5)");
        return;
    }
    let fast = reverb::util::bench::fast_mode();
    let train_steps = if fast { 50 } else { 200 };

    println!("# E2E DQN pipeline (CartPole, PER, SPI=8, 2 actors)");
    println!("| actors | train steps | train/s | env steps/s | realized SPI |");
    println!("|---|---|---|---|---|");
    for actors in [1usize, 2, 4] {
        let (replay, vars) = DqnConfig::default()
            .replay_tables(100_000, 0.6, 8.0, 64, 4096.0)
            .unwrap();
        let server = Server::builder()
            .table(replay)
            .table(vars)
            .bind("127.0.0.1:0")
            .unwrap();
        let config = DqnConfig {
            num_actors: actors,
            train_steps,
            publish_period: 25,
            // Same-process harness → zero-copy in-process transport.
            ..DqnConfig::for_server(&server)
        };
        let report = run_dqn(config).unwrap();
        let secs = report.wall.as_secs_f64();
        println!(
            "| {actors} | {train_steps} | {:.1} | {:.0} | {:.2} |",
            train_steps as f64 / secs,
            report.env_steps as f64 / secs,
            report.realized_spi,
        );
    }
}
