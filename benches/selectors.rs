//! §3.3 ablation: per-operation cost of every Selector strategy vs table
//! size. Selectors must stay cheap because they run under the table mutex;
//! this bench documents the O(1)/O(log n) behaviour of each.
//!
//! Run: `cargo bench --bench selectors`

use reverb::core::selector::SelectorConfig;
use reverb::util::rng::Pcg32;
use std::time::Instant;

fn bench_selector(cfg: SelectorConfig, n: usize) -> (f64, f64, f64) {
    let mut s = cfg.build();
    let mut rng = Pcg32::new(1, 1);
    // Fill.
    let t0 = Instant::now();
    for k in 0..n as u64 {
        s.insert(k, rng.gen_f64() * 10.0).unwrap();
    }
    let insert_ns = t0.elapsed().as_nanos() as f64 / n as f64;
    // Select.
    let reps = 100_000;
    let t1 = Instant::now();
    for _ in 0..reps {
        s.select(&mut rng).unwrap();
    }
    let select_ns = t1.elapsed().as_nanos() as f64 / reps as f64;
    // Update.
    let t2 = Instant::now();
    for k in 0..(n as u64).min(100_000) {
        s.update(k, rng.gen_f64() * 10.0).unwrap();
    }
    let update_ns = t2.elapsed().as_nanos() as f64 / (n as f64).min(100_000.0);
    (insert_ns, select_ns, update_ns)
}

fn main() {
    println!("# Selector per-op cost (ns) vs table size");
    println!("| selector | size | insert | select | update |");
    println!("|---|---|---|---|---|");
    for cfg in [
        SelectorConfig::Fifo,
        SelectorConfig::Lifo,
        SelectorConfig::Uniform,
        SelectorConfig::MaxHeap,
        SelectorConfig::MinHeap,
        SelectorConfig::Prioritized { exponent: 0.8 },
    ] {
        for &n in &[1_000usize, 100_000, 1_000_000] {
            let (ins, sel, upd) = bench_selector(cfg, n);
            println!(
                "| {:?} | {n} | {ins:.0} | {sel:.0} | {upd:.0} |",
                cfg
            );
        }
    }
    println!("\nuniform select is O(1); heaps/prioritized are O(log n); fifo/lifo use a BTree (O(log n)).");
}
