//! §3.4 ablation: RateLimiter SPI enforcement under imbalanced
//! producer/consumer speeds.
//!
//! Scenario: writers and samplers with deliberately mismatched speeds
//! hammer a SampleToInsertRatio(SPI, min_size, buffer) table; whatever the
//! imbalance, the realized samples/insert ratio must converge to the
//! target and the cursor stay inside the error-buffer corridor, with the
//! faster side blocking. Also measures the overhead: the same workload on
//! a MinSize(1) table (no SPI constraint).
//!
//! Run: `cargo bench --bench rate_limiter`

use reverb::core::rate_limiter::RateLimiterConfig;
use reverb::core::table::{Table, TableConfig};
use reverb::util::bench::random_step;
use reverb::util::rng::Pcg32;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

fn run(limiter: RateLimiterConfig, writers: usize, samplers: usize, writer_delay_us: u64) -> (f64, f64, u64, u64) {
    let cfg = TableConfig {
        rate_limiter: limiter,
        ..TableConfig::uniform_replay("t", 1_000_000)
    };
    let table = Arc::new(Table::new(cfg));
    let stop = Arc::new(AtomicBool::new(false));
    let mut handles = Vec::new();
    for w in 0..writers {
        let table = table.clone();
        let stop = stop.clone();
        handles.push(std::thread::spawn(move || {
            let mut rng = Pcg32::new(3, w as u64);
            let mut k = (w as u64) << 40;
            while !stop.load(Ordering::Relaxed) {
                if writer_delay_us > 0 {
                    std::thread::sleep(Duration::from_micros(writer_delay_us));
                }
                let step = random_step(16, &mut rng);
                let chunk = reverb::core::chunk::Chunk::from_steps(
                    k | 1 << 63, 0, &[step], reverb::core::chunk::Compression::None,
                ).unwrap();
                let item = reverb::core::item::Item::new(
                    k, "t", 1.0, vec![Arc::new(chunk)], 0, 1,
                ).unwrap();
                k += 1;
                let _ = table.insert_or_assign(item, Some(Duration::from_millis(20)));
            }
        }));
    }
    for _ in 0..samplers {
        let table = table.clone();
        let stop = stop.clone();
        handles.push(std::thread::spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                let _ = table.sample_batch(16, Some(Duration::from_millis(20)));
            }
        }));
    }
    std::thread::sleep(Duration::from_millis(800));
    stop.store(true, Ordering::Relaxed);
    table.cancel();
    for h in handles {
        h.join().unwrap();
    }
    let info = table.info();
    (
        info.samples as f64 / info.inserts.max(1) as f64,
        info.diff,
        info.rate_limited_inserts,
        info.rate_limited_samples,
    )
}

fn main() {
    println!("# RateLimiter: realized SPI under imbalanced workloads (target SPI = 4)");
    println!("| scenario | limiter | realized SPI | cursor diff | blocked ins | blocked smp |");
    println!("|---|---|---|---|---|---|");
    let spi = RateLimiterConfig::sample_to_insert_ratio(4.0, 10, 64.0).unwrap();
    let unlimited = RateLimiterConfig::min_size(1);
    for (name, writers, samplers, delay) in [
        ("balanced 2w/2s", 2usize, 2usize, 0u64),
        ("fast writers 4w/1s", 4, 1, 0),
        ("slow writers 1w/4s", 1, 4, 200),
    ] {
        let (r_spi, diff, bi, bs) = run(spi, writers, samplers, delay);
        println!("| {name} | SPI=4±buf | {r_spi:.2} | {diff:.0} | {bi} | {bs} |");
        let (u_spi, _, _, _) = run(unlimited, writers, samplers, delay);
        println!("| {name} | MinSize(1) | {u_spi:.2} | - | - | - |");
    }
    println!("\nwith the SPI limiter the realized ratio pins to 4 regardless of the speed");
    println!("imbalance (the faster side blocks); MinSize lets it drift freely.");
}
