//! Figure 5 reproduction: single-server INSERT throughput (BPS & QPS) vs
//! number of concurrent clients, for payloads 400 B → 400 kB.
//!
//! Paper setup (§5): random f32 tensors (incompressible), chunk & sequence
//! length 1 (no sharing), clients insert flat out. Expected shape: linear
//! scaling with client count until a QPS or BPS ceiling, then a flat
//! plateau — adding clients past saturation must NOT degrade throughput.
//!
//! Clients are threads over loopback TCP (DESIGN.md §2); absolute ceilings
//! are loopback-bound, the shape is the result.
//!
//! Run: `cargo bench --bench fig5_insert_scaling`
//! (REVERB_BENCH_FAST=1 for a quick pass.)

use reverb::core::table::TableConfig;
use reverb::net::server::Server;
use reverb::util::bench::*;
use reverb::util::stats::{fmt_bps, fmt_qps};

fn main() {
    println!("# Figure 5: insert scaling (clients are loopback threads)");
    println!("| payload | clients | QPS | BPS | per-client QPS |");
    println!("|---|---|---|---|---|");
    let mut peak: Vec<(String, f64, f64)> = Vec::new();
    for &(floats, label) in PAYLOAD_SIZES {
        let mut best_qps: f64 = 0.0;
        let mut best_bps: f64 = 0.0;
        for &clients in &client_counts() {
            // Fresh server per point: FIFO eviction at max_size keeps the
            // table bounded, matching the paper's steady-state overwrite.
            let server = Server::builder()
                .table(TableConfig::uniform_replay("t", 200_000))
                .bind("127.0.0.1:0")
                .unwrap();
            let t = run_insert_clients(
                &server.local_addr().to_string(),
                &["t".to_string()],
                clients,
                floats,
                window(),
            );
            best_qps = best_qps.max(t.qps());
            best_bps = best_bps.max(t.bps());
            print_row(&[
                label.to_string(),
                clients.to_string(),
                fmt_qps(t.qps()),
                fmt_bps(t.bps()),
                fmt_qps(t.qps() / clients as f64),
            ]);
        }
        peak.push((label.to_string(), best_qps, best_bps));
    }
    println!("\n## Peak insert throughput per payload (paper: ~60k items/s or ~11 GB/s)");
    for (label, qps, bps) in peak {
        println!("  {label}: {} / {}", fmt_qps(qps), fmt_bps(bps));
    }
}
